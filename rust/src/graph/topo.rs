//! Topological ordering and reachability over the DAG.

use super::ir::{Graph, NodeId};
use crate::error::AladinError;
use std::collections::VecDeque;

/// Kahn's algorithm topological sort over activation+parameter edges.
///
/// Returns nodes in dependency order, or an error naming a node on a cycle
/// (a malformed "DAG" — e.g. produced by a buggy import).
pub fn topo_sort(g: &Graph) -> Result<Vec<NodeId>, AladinError> {
    let n = g.nodes.len();
    let mut indeg = vec![0usize; n];
    for e in &g.edges {
        if e.from.is_some() {
            for &t in &e.to {
                indeg[t.0] += 1;
            }
        }
    }
    let mut queue: VecDeque<NodeId> = (0..n)
        .filter(|&i| indeg[i] == 0)
        .map(NodeId)
        .collect();
    let mut order = Vec::with_capacity(n);
    while let Some(u) = queue.pop_front() {
        order.push(u);
        for eid in &g.nodes[u.0].outputs {
            for &t in &g.edges[eid.0].to {
                indeg[t.0] -= 1;
                if indeg[t.0] == 0 {
                    queue.push_back(t);
                }
            }
        }
    }
    if order.len() != n {
        let stuck = (0..n).find(|&i| indeg[i] > 0).map(NodeId).unwrap();
        return Err(AladinError::GraphCycle {
            node: g.node(stuck).name.clone(),
        });
    }
    Ok(order)
}

/// Nodes reachable from the graph inputs by following activation edges.
pub fn reachable_from_inputs(g: &Graph) -> Vec<bool> {
    let mut seen = vec![false; g.nodes.len()];
    let mut stack: Vec<NodeId> = g.inputs();
    for &s in &stack {
        seen[s.0] = true;
    }
    while let Some(u) = stack.pop() {
        for v in g.successors(u) {
            if !seen[v.0] {
                seen[v.0] = true;
                stack.push(v);
            }
        }
    }
    seen
}

/// The linear chain of compute nodes (everything except Input/Output) in
/// topological order — the common case for the sequential CNNs analyzed in
/// the paper.
pub fn compute_order(g: &Graph) -> Result<Vec<NodeId>, AladinError> {
    Ok(topo_sort(g)?
        .into_iter()
        .filter(|&id| {
            !matches!(
                g.node(id).op,
                super::ir::Op::Input | super::ir::Op::Output
            )
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::*;
    use crate::graph::tensor::*;

    fn chain(len: usize) -> Graph {
        let mut g = Graph::new("chain");
        let spec = TensorSpec::chw(1, 4, 4, ElemType::int(8));
        let mut prev = g.add_node("in", Op::Input);
        let mut prev_edge = g.add_edge("e0", spec.clone(), EdgeKind::Activation);
        g.connect_output(prev, prev_edge);
        for i in 0..len {
            let n = g.add_node(format!("relu{i}"), Op::Relu);
            g.connect_input(n, prev_edge);
            let e = g.add_edge(format!("e{}", i + 1), spec.clone(), EdgeKind::Activation);
            g.connect_output(n, e);
            prev = n;
            prev_edge = e;
        }
        let out = g.add_node("out", Op::Output);
        g.connect_input(out, prev_edge);
        let _ = prev;
        g
    }

    #[test]
    fn topo_sort_orders_chain() {
        let g = chain(5);
        let order = topo_sort(&g).unwrap();
        assert_eq!(order.len(), g.nodes.len());
        // each node must appear after its predecessor
        let pos: Vec<usize> = {
            let mut p = vec![0; g.nodes.len()];
            for (i, id) in order.iter().enumerate() {
                p[id.0] = i;
            }
            p
        };
        for e in &g.edges {
            if let Some(f) = e.from {
                for t in &e.to {
                    assert!(pos[f.0] < pos[t.0]);
                }
            }
        }
    }

    #[test]
    fn cycle_detected() {
        let mut g = chain(2);
        // create a back edge: relu2 -> relu1
        let e = g.add_edge(
            "back",
            TensorSpec::chw(1, 4, 4, ElemType::int(8)),
            EdgeKind::Activation,
        );
        let relu1 = NodeId(1);
        let relu2 = NodeId(2);
        g.connect_output(relu2, e);
        g.connect_input(relu1, e);
        assert!(matches!(topo_sort(&g), Err(AladinError::GraphCycle { .. })));
    }

    #[test]
    fn compute_order_skips_io() {
        let g = chain(3);
        let order = compute_order(&g).unwrap();
        assert_eq!(order.len(), 3);
        for id in order {
            assert_eq!(g.node(id).op.kind(), "Relu");
        }
    }

    #[test]
    fn reachability_covers_chain() {
        let g = chain(4);
        let seen = reachable_from_inputs(&g);
        assert!(seen.iter().all(|&b| b));
    }
}
