//! Structural + shape validation of QNN graphs.
//!
//! Catches malformed imports and builder misuse before the analysis passes
//! run: dangling edges, arity violations, shape mismatches between a node's
//! attributes and its connected edge specs, and unreachable nodes.

use super::ir::*;
use super::topo;
use crate::error::{AladinError, Result};

/// Validate a canonical or implementation-aware graph.
pub fn validate(g: &Graph) -> Result<()> {
    // acyclicity first: everything else assumes a DAG
    topo::topo_sort(g)?;

    for e in &g.edges {
        if e.to.is_empty() && e.from.is_none() {
            return Err(AladinError::Validation {
                at: e.name.clone(),
                reason: "edge has neither producer nor consumer".into(),
            });
        }
        if e.is_param() && e.from.is_some() {
            return Err(AladinError::Validation {
                at: e.name.clone(),
                reason: "parameter edge has a producer".into(),
            });
        }
        if e.spec.dims.is_empty() || e.spec.num_elems() == 0 {
            return Err(AladinError::Validation {
                at: e.name.clone(),
                reason: "edge carries an empty tensor".into(),
            });
        }
        if e.spec.elem.bits == 0 || e.spec.elem.bits > 32 {
            return Err(AladinError::Validation {
                at: e.name.clone(),
                reason: format!("unsupported bit-width {}", e.spec.elem.bits),
            });
        }
    }

    for n in &g.nodes {
        validate_node(g, n)?;
    }

    let seen = topo::reachable_from_inputs(g);
    if let Some(i) = seen.iter().position(|&b| !b) {
        return Err(AladinError::Validation {
            at: g.nodes[i].name.clone(),
            reason: "node unreachable from graph inputs".into(),
        });
    }
    Ok(())
}

fn expect(cond: bool, at: &str, reason: impl Into<String>) -> Result<()> {
    if cond {
        Ok(())
    } else {
        Err(AladinError::Validation {
            at: at.into(),
            reason: reason.into(),
        })
    }
}

fn validate_node(g: &Graph, n: &Node) -> Result<()> {
    let at = n.name.as_str();
    let data_in = g.data_input(n.id);
    let out = g.output_edge(n.id);
    match &n.op {
        Op::Input => expect(n.inputs.is_empty(), at, "Input node must have no inputs"),
        Op::Output => expect(n.outputs.is_empty(), at, "Output node must have no outputs"),
        Op::Conv(a) => {
            let x = data_in.ok_or_else(|| AladinError::Validation {
                at: at.into(),
                reason: "Conv missing data input".into(),
            })?;
            expect(x.spec.dims.len() == 3, at, "Conv input must be [C,H,W]")?;
            let cin = x.spec.dims[0];
            expect(a.groups > 0, at, "Conv groups must be positive")?;
            expect(
                cin % a.groups == 0,
                at,
                format!("in_channels {cin} not divisible by groups {}", a.groups),
            )?;
            expect(
                a.out_channels % a.groups == 0,
                at,
                format!(
                    "out_channels {} not divisible by groups {}",
                    a.out_channels, a.groups
                ),
            )?;
            let params = g.param_inputs(n.id);
            expect(!params.is_empty(), at, "Conv missing weight parameter")?;
            let w = &params[0].spec;
            let want = vec![a.out_channels, cin / a.groups, a.kernel.0, a.kernel.1];
            if w.dims != want {
                return Err(AladinError::ShapeMismatch {
                    at: at.into(),
                    expected: format!("{want:?}"),
                    got: format!("{:?}", w.dims),
                });
            }
            if let Some(o) = out {
                let (oh, ow) = a.out_hw(x.spec.dims[1], x.spec.dims[2]);
                let want = vec![a.out_channels, oh, ow];
                if o.spec.dims != want {
                    return Err(AladinError::ShapeMismatch {
                        at: at.into(),
                        expected: format!("{want:?}"),
                        got: format!("{:?}", o.spec.dims),
                    });
                }
            }
            Ok(())
        }
        Op::Gemm(a) => {
            let x = data_in.ok_or_else(|| AladinError::Validation {
                at: at.into(),
                reason: "Gemm missing data input".into(),
            })?;
            expect(x.spec.dims.len() == 1, at, "Gemm input must be flattened [F]")?;
            let params = g.param_inputs(n.id);
            expect(!params.is_empty(), at, "Gemm missing weight parameter")?;
            let w = &params[0].spec;
            let want = vec![a.out_features, x.spec.dims[0]];
            if w.dims != want {
                return Err(AladinError::ShapeMismatch {
                    at: at.into(),
                    expected: format!("{want:?}"),
                    got: format!("{:?}", w.dims),
                });
            }
            Ok(())
        }
        Op::MatMul(a) => {
            expect(a.m > 0 && a.k > 0 && a.n > 0, at, "MatMul dims must be positive")
        }
        Op::Quant(a) => {
            let x = data_in.ok_or_else(|| AladinError::Validation {
                at: at.into(),
                reason: "Quant missing data input".into(),
            })?;
            expect(
                a.to.bits <= x.spec.elem.bits,
                at,
                format!(
                    "requantization must not widen: {} -> {}",
                    x.spec.elem, a.to
                ),
            )?;
            // every fanned-out consumer reads the requantized precision, so
            // each output edge must agree with the target attribute
            for eid in &n.outputs {
                let o = g.edge(*eid);
                expect(
                    o.spec.elem == a.to,
                    at,
                    format!(
                        "Quant output edge `{}` elem {} != target {}",
                        o.name, o.spec.elem, a.to
                    ),
                )?;
            }
            Ok(())
        }
        Op::Relu | Op::Add => {
            if let (Some(x), Some(o)) = (data_in, out) {
                expect(
                    x.spec.dims == o.spec.dims,
                    at,
                    "elementwise op must preserve shape",
                )?;
            }
            Ok(())
        }
        Op::MaxPool(a) | Op::AvgPool(a) => {
            let x = data_in.ok_or_else(|| AladinError::Validation {
                at: at.into(),
                reason: "Pool missing data input".into(),
            })?;
            expect(x.spec.dims.len() == 3, at, "Pool input must be [C,H,W]")?;
            if let Some(o) = out {
                let (oh, ow) = a.out_hw(x.spec.dims[1], x.spec.dims[2]);
                let want = vec![x.spec.dims[0], oh, ow];
                if o.spec.dims != want {
                    return Err(AladinError::ShapeMismatch {
                        at: at.into(),
                        expected: format!("{want:?}"),
                        got: format!("{:?}", o.spec.dims),
                    });
                }
            }
            Ok(())
        }
        Op::Flatten => {
            if let (Some(x), Some(o)) = (data_in, out) {
                expect(
                    x.spec.num_elems() == o.spec.num_elems(),
                    at,
                    "Flatten must preserve element count",
                )?;
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::tensor::{ElemType, TensorSpec};

    fn valid_graph() -> Graph {
        let mut b = GraphBuilder::new(
            "v",
            TensorSpec::chw(3, 16, 16, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(8, 3, 1, 1), ElemType::int(8))
            .relu("r0")
            .quant("q0", ElemType::int(8), false)
            .max_pool("p0", PoolAttrs::square(2, 2))
            .flatten("f")
            .gemm("fc", 10, ElemType::int(8));
        b.finish()
    }

    #[test]
    fn builder_output_validates() {
        validate(&valid_graph()).unwrap();
    }

    #[test]
    fn rejects_widening_quant() {
        let mut g = valid_graph();
        // corrupt quant target to widen 32 -> impossible via builder, force it:
        for n in &mut g.nodes {
            if let Op::Quant(q) = &mut n.op {
                q.to = ElemType::int(8);
            }
        }
        // make the quant *input* narrower than target
        let qid = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Op::Quant(_)))
            .unwrap()
            .id;
        let in_edge = g.nodes[qid.0].inputs[0];
        g.edges[in_edge.0].spec.elem = ElemType::int(4);
        assert!(validate(&g).is_err());
    }

    #[test]
    fn rejects_bad_weight_shape() {
        let mut g = valid_graph();
        // find conv weight edge and corrupt it
        let w = g
            .edges
            .iter()
            .position(|e| e.name == "c0.weight")
            .unwrap();
        g.edges[w].spec.dims = vec![8, 3, 5, 5];
        assert!(matches!(
            validate(&g),
            Err(AladinError::ShapeMismatch { .. })
        ));
    }

    #[test]
    fn rejects_dangling_edge() {
        let mut g = valid_graph();
        g.add_edge(
            "dangling",
            TensorSpec::chw(1, 1, 1, ElemType::int(8)),
            EdgeKind::Activation,
        );
        assert!(validate(&g).is_err());
    }

    #[test]
    fn rejects_groups_not_dividing_out_channels() {
        let mut g = valid_graph();
        for n in &mut g.nodes {
            if let Op::Conv(a) = &mut n.op {
                // 3 input channels % 3 == 0 but 8 output channels % 3 != 0
                a.groups = 3;
            }
        }
        let err = validate(&g).unwrap_err().to_string();
        assert!(err.contains("out_channels"), "{err}");
    }

    #[test]
    fn rejects_zero_groups() {
        let mut g = valid_graph();
        for n in &mut g.nodes {
            if let Op::Conv(a) = &mut n.op {
                a.groups = 0;
            }
        }
        assert!(validate(&g).is_err());
    }

    #[test]
    fn rejects_quant_target_edge_disagreement() {
        let mut g = valid_graph();
        let qid = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Op::Quant(_)))
            .unwrap()
            .id;
        let out = g.nodes[qid.0].outputs[0];
        // the attribute says int8 but the edge claims int4 storage
        g.edges[out.0].spec.elem = ElemType::int(4);
        let err = validate(&g).unwrap_err().to_string();
        assert!(err.contains("!= target"), "{err}");
    }

    #[test]
    fn rejects_zero_bitwidth() {
        let mut g = valid_graph();
        g.edges[0].spec.elem.bits = 0;
        assert!(validate(&g).is_err());
    }
}
