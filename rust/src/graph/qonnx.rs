//! QONNX-dialect JSON import/export.
//!
//! The paper's workflow starts from a QONNX file (ONNX + arbitrary-precision
//! Quant nodes). We do not link against protobuf-ONNX; instead we define a
//! faithful JSON projection of the QONNX subset the paper uses (Quant, Conv,
//! Gemm, Relu, MaxPool/AvgPool, Flatten, Add) and convert it to/from the
//! internal [`Graph`]. `python/compile/export_qonnx.py` emits the same
//! dialect from the JAX model, closing the toolchain loop.

use super::ir::*;
use super::tensor::{ElemType, TensorSpec};
use super::validate;
use crate::error::{AladinError, Result};
use crate::util::json::{self, pull, Value};
use std::borrow::Cow;
use std::collections::HashMap;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

/// Initializer payload of a tensor declaration.
///
/// Production-size documents carry hundreds of MB of weight data that the
/// analyze/DSE flows never read. The streaming ingest path
/// ([`crate::graph::qonnx_stream`]) therefore records `Lazy` byte spans on
/// its single pass over the document and decodes them only on demand;
/// `Inline` holds values that were decoded eagerly (or built in memory).
#[derive(Debug, Clone)]
pub enum TensorData {
    /// Decoded integer payload, flattened in row-major order.
    Inline(Vec<i64>),
    /// Undecoded byte span into the source document (shared, not copied).
    /// Structure was validated on the ingest pass; element integer-ness
    /// and length-vs-dims are deferred to the on-demand decode.
    Lazy {
        /// Byte range of the JSON `data` array within `source`.
        span: pull::ByteSpan,
        /// The full source document the span indexes into. `Arc<Vec<u8>>`
        /// rather than `Arc<[u8]>` so adopting an owned buffer never
        /// copies it (`Arc::from(Vec)` would).
        source: Arc<Vec<u8>>,
    },
}

impl TensorData {
    /// True when the payload is still an undecoded byte span.
    pub fn is_lazy(&self) -> bool {
        matches!(self, TensorData::Lazy { .. })
    }

    /// Bytes the payload occupies in the source document (lazy spans
    /// only) — the "weight data never materialized" ledger the ingest
    /// diagnostics report.
    pub fn lazy_bytes(&self) -> usize {
        match self {
            TensorData::Inline(_) => 0,
            TensorData::Lazy { span, .. } => span.len(),
        }
    }

    /// The integer payload, decoding a lazy span on demand — borrowed for
    /// inline data, owned for a freshly-decoded span.
    pub fn values(&self) -> Result<Cow<'_, [i64]>> {
        match self {
            TensorData::Inline(v) => Ok(Cow::Borrowed(v.as_slice())),
            TensorData::Lazy { span, source } => {
                Ok(Cow::Owned(decode_data_window(&source[span.start..span.end])?))
            }
        }
    }
}

// Payload equality is semantic: a lazy span equals the inline values it
// decodes to, so round-trip tests can compare models across policies.
impl PartialEq for TensorData {
    fn eq(&self, other: &Self) -> bool {
        match (self.values(), other.values()) {
            (Ok(a), Ok(b)) => a == b,
            _ => false,
        }
    }
}

/// Decode a recorded `data` span as a flat array of integers.
fn decode_data_window(window: &[u8]) -> Result<Vec<i64>> {
    let mut p = pull::PullParser::new(window);
    if p.next_event()? != pull::Event::BeginArray {
        return Err(parse_err("tensor data must be an array of integers"));
    }
    let mut out = Vec::new();
    loop {
        match p.next_event()? {
            pull::Event::Num(n) => out.push(num_to_i64(n)?),
            pull::Event::EndArray => break,
            _ => return Err(parse_err("tensor data entries must be integers")),
        }
    }
    Ok(out)
}

/// Integer check shared by both decode paths — mirrors `Value::as_i64`
/// (fractional values rejected, range clamped by the f64→i64 cast) so the
/// DOM and streaming ingests stay bit-identical.
pub(crate) fn num_to_i64(n: f64) -> Result<i64> {
    if n.fract() == 0.0 {
        Ok(n as i64)
    } else {
        Err(parse_err("tensor data entries must be integers"))
    }
}

/// A QONNX-dialect decode error.
pub(crate) fn parse_err(reason: impl Into<String>) -> AladinError {
    AladinError::Parse {
        at: "qonnx".into(),
        reason: reason.into(),
    }
}

/// Checked `dims` product shared by both decode paths (`None` on
/// overflow, which the callers report as a length mismatch).
pub(crate) fn dims_product(dims: &[usize]) -> Option<usize> {
    dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
}

/// Eager-decode consistency check: inline payload length must equal the
/// dims product. Lazy spans defer this to their on-demand decode site.
pub(crate) fn check_data_len(name: &str, dims: &[usize], len: usize) -> Result<()> {
    match dims_product(dims) {
        Some(p) if p == len => Ok(()),
        _ => Err(parse_err(format!(
            "tensor `{name}` data length {len} does not match dims product"
        ))),
    }
}

/// One node of the on-disk QONNX-dialect document.
#[derive(Debug, Clone, PartialEq)]
pub struct QonnxNode {
    /// Unique node name.
    pub name: String,
    /// Operator type: "Quant" | "Conv" | "Gemm" | "Relu" | "MaxPool"
    /// | "AveragePool" | "Flatten" | "Add".
    pub op_type: String,
    /// Names of input tensors (activations then initializers).
    pub inputs: Vec<String>,
    /// Names of output tensors.
    pub outputs: Vec<String>,
    /// Operator attributes (kernel_shape, strides, pads, group, bits, …).
    pub attributes: HashMap<String, Value>,
}

/// Tensor type declaration.
#[derive(Debug, Clone, PartialEq)]
pub struct QonnxTensor {
    /// Tensor name, referenced by node inputs/outputs.
    pub name: String,
    /// Dimensions, outermost first.
    pub dims: Vec<usize>,
    /// Bit-width of each element.
    pub bits: u8,
    /// Two's-complement signedness.
    pub signed: bool,
    /// True for constant initializers (weights, biases, thresholds).
    pub initializer: bool,
    /// Optional integer payload (weights/biases). `None` for activations,
    /// for documents that declare shapes only, and for ingests run with
    /// [`crate::graph::qonnx_stream::DataPolicy::Skip`].
    pub data: Option<TensorData>,
}

/// On-disk QONNX-dialect document.
#[derive(Debug, Clone, PartialEq)]
pub struct QonnxModel {
    /// Model name.
    pub name: String,
    /// Names of the graph's input tensors.
    pub graph_inputs: Vec<String>,
    /// Names of the graph's output tensors.
    pub graph_outputs: Vec<String>,
    /// All tensor declarations (activations and initializers).
    pub tensors: Vec<QonnxTensor>,
    /// Operation nodes in document order.
    pub nodes: Vec<QonnxNode>,
}

fn attr_usize(n: &QonnxNode, key: &str) -> Option<usize> {
    n.attributes.get(key).and_then(|v| v.as_u64()).map(|v| v as usize)
}

fn attr_pair(n: &QonnxNode, key: &str) -> Option<(usize, usize)> {
    let arr = n.attributes.get(key)?.as_arr()?;
    let a = arr.first()?.as_u64()? as usize;
    let b = arr.get(1).and_then(|v| v.as_u64()).unwrap_or(a as u64) as usize;
    Some((a, b))
}

/// Per-tensor JSON rendering shared by the DOM serializer and the
/// streaming pretty writer — lazy payloads decode one tensor at a time.
fn tensor_to_json(t: &QonnxTensor) -> Result<Value> {
    let mut v = Value::obj()
        .with("name", t.name.clone())
        .with("dims", t.dims.clone())
        .with("bits", t.bits)
        .with("signed", t.signed)
        .with("initializer", t.initializer);
    if let Some(data) = &t.data {
        let vals = data.values()?;
        v.set("data", Value::Arr(vals.iter().map(|&x| Value::from(x)).collect()));
    }
    Ok(v)
}

/// Per-node JSON rendering (attributes sorted for determinism).
fn node_to_json(n: &QonnxNode) -> Value {
    let mut attrs: Vec<(String, Value)> =
        n.attributes.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    attrs.sort_by(|a, b| a.0.cmp(&b.0));
    Value::obj()
        .with("name", n.name.clone())
        .with("op_type", n.op_type.clone())
        .with("inputs", n.inputs.clone())
        .with("outputs", n.outputs.clone())
        .with("attributes", Value::Obj(attrs))
}

/// Decode one tensor declaration from its DOM object — semantics mirrored
/// exactly by `qonnx_stream`'s event-driven decoder.
fn tensor_from_json(t: &Value) -> Result<QonnxTensor> {
    let name = t
        .str_field("name")
        .ok_or_else(|| parse_err("tensor missing name"))?
        .to_string();
    let dims = t
        .get("dims")
        .and_then(|d| d.as_arr())
        .ok_or_else(|| parse_err(format!("tensor `{name}` missing dims")))?
        .iter()
        .map(|x| {
            x.as_usize().ok_or_else(|| {
                parse_err(format!("tensor `{name}` dims entries must be non-negative integers"))
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let bits = t
        .u64_field("bits")
        .ok_or_else(|| parse_err(format!("tensor `{name}` missing bits")))?;
    if bits == 0 || bits > u64::from(u8::MAX) {
        return Err(parse_err(format!("tensor `{name}` bits {bits} out of range 1..=255")));
    }
    let signed = match t.get("signed") {
        None => true,
        Some(b) => b
            .as_bool()
            .ok_or_else(|| parse_err(format!("tensor `{name}` signed must be a boolean")))?,
    };
    let initializer = match t.get("initializer") {
        None => false,
        Some(b) => b
            .as_bool()
            .ok_or_else(|| parse_err(format!("tensor `{name}` initializer must be a boolean")))?,
    };
    let data = match t.get("data") {
        None => None,
        Some(d) => {
            let arr = d.as_arr().ok_or_else(|| {
                parse_err(format!("tensor `{name}` data must be an array of integers"))
            })?;
            let vals = arr
                .iter()
                .map(|x| {
                    x.as_i64().ok_or_else(|| parse_err("tensor data entries must be integers"))
                })
                .collect::<Result<Vec<_>>>()?;
            check_data_len(&name, &dims, vals.len())?;
            Some(TensorData::Inline(vals))
        }
    };
    Ok(QonnxTensor {
        name,
        dims,
        bits: bits as u8,
        signed,
        initializer,
        data,
    })
}

/// Decode one operation node from its DOM object — semantics mirrored
/// exactly by `qonnx_stream`'s event-driven decoder.
fn node_from_json(n: &Value) -> Result<QonnxNode> {
    let name = n
        .str_field("name")
        .ok_or_else(|| parse_err("node missing name"))?
        .to_string();
    let op_type = n
        .str_field("op_type")
        .ok_or_else(|| parse_err(format!("node `{name}` missing op_type")))?
        .to_string();
    let list = |key: &str| -> Result<Vec<String>> {
        match n.get(key) {
            None => Ok(Vec::new()),
            Some(a) => a
                .as_arr()
                .ok_or_else(|| parse_err(format!("node `{name}` `{key}` must be an array")))?
                .iter()
                .map(|s| {
                    s.as_str().map(String::from).ok_or_else(|| {
                        parse_err(format!("node `{name}` `{key}` entries must be strings"))
                    })
                })
                .collect(),
        }
    };
    let inputs = list("inputs")?;
    let outputs = list("outputs")?;
    let attributes = match n.get("attributes") {
        None => HashMap::new(),
        Some(o) => o
            .as_obj()
            .ok_or_else(|| parse_err(format!("node `{name}` attributes must be an object")))?
            .iter()
            .cloned()
            .collect(),
    };
    Ok(QonnxNode {
        name,
        op_type,
        inputs,
        outputs,
        attributes,
    })
}

impl QonnxModel {
    /// Read and parse a QONNX-dialect JSON file.
    ///
    /// Routes through the streaming ingest
    /// ([`crate::graph::qonnx_stream`]) with
    /// [`DataPolicy::Lazy`](crate::graph::qonnx_stream::DataPolicy::Lazy):
    /// no DOM `Value` tree is materialized, and initializer payloads stay
    /// as byte spans until something actually reads them — which the
    /// analyze/eval/DSE flows never do.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        super::qonnx_stream::from_file(path, super::qonnx_stream::DataPolicy::Lazy)
    }

    /// Write the document as pretty-printed JSON, streaming tensor by
    /// tensor — exporting a large model does not double peak memory by
    /// assembling the whole text in a `String` first.
    pub fn to_file(&self, path: impl AsRef<Path>) -> Result<()> {
        let file = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::new(file);
        self.write_pretty(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Parse from the in-tree JSON document model (the DOM path, kept for
    /// small in-memory documents and as the differential-test reference).
    /// Decode semantics are identical to the streaming path; the property
    /// suite in `tests/qonnx_stream.rs` holds the two bit-identical.
    pub fn from_json(v: &Value) -> Result<Self> {
        let strings = |key: &str| -> Result<Vec<String>> {
            v.get(key)
                .and_then(|a| a.as_arr())
                .ok_or_else(|| parse_err(format!("missing `{key}` array")))?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(String::from)
                        .ok_or_else(|| parse_err(format!("`{key}` entries must be strings")))
                })
                .collect()
        };
        let name = match v.get("name") {
            None => "model".to_string(),
            Some(n) => n
                .as_str()
                .ok_or_else(|| parse_err("`name` must be a string"))?
                .to_string(),
        };
        let tensors = v
            .get("tensors")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| parse_err("missing `tensors`"))?
            .iter()
            .map(tensor_from_json)
            .collect::<Result<Vec<_>>>()?;
        let nodes = v
            .get("nodes")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| parse_err("missing `nodes`"))?
            .iter()
            .map(node_from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(QonnxModel {
            name,
            graph_inputs: strings("graph_inputs")?,
            graph_outputs: strings("graph_outputs")?,
            tensors,
            nodes,
        })
    }

    /// Render to the in-tree JSON document model. Fallible because lazy
    /// initializer payloads are decoded here (one tensor at a time).
    pub fn to_json(&self) -> Result<Value> {
        let tensors = self
            .tensors
            .iter()
            .map(tensor_to_json)
            .collect::<Result<Vec<_>>>()?;
        let nodes: Vec<Value> = self.nodes.iter().map(node_to_json).collect();
        Ok(Value::obj()
            .with("name", self.name.clone())
            .with("graph_inputs", self.graph_inputs.clone())
            .with("graph_outputs", self.graph_outputs.clone())
            .with("tensors", Value::Arr(tensors))
            .with("nodes", Value::Arr(nodes)))
    }

    /// Stream the document as pretty-printed JSON into `w`, byte-identical
    /// to `self.to_json()?.to_string_pretty()` but materializing at most
    /// one tensor/node sub-document at a time.
    pub fn write_pretty<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(b"{\n  \"name\": ")?;
        json::write_escaped_str(w, &self.name)?;
        w.write_all(b",\n  \"graph_inputs\": ")?;
        Value::from(self.graph_inputs.clone()).write_pretty_depth(w, 1)?;
        w.write_all(b",\n  \"graph_outputs\": ")?;
        Value::from(self.graph_outputs.clone()).write_pretty_depth(w, 1)?;
        w.write_all(b",\n  \"tensors\": ")?;
        if self.tensors.is_empty() {
            w.write_all(b"[]")?;
        } else {
            w.write_all(b"[")?;
            for (i, t) in self.tensors.iter().enumerate() {
                if i > 0 {
                    w.write_all(b",")?;
                }
                w.write_all(b"\n    ")?;
                tensor_to_json(t)?.write_pretty_depth(w, 2)?;
            }
            w.write_all(b"\n  ]")?;
        }
        w.write_all(b",\n  \"nodes\": ")?;
        if self.nodes.is_empty() {
            w.write_all(b"[]")?;
        } else {
            w.write_all(b"[")?;
            for (i, n) in self.nodes.iter().enumerate() {
                if i > 0 {
                    w.write_all(b",")?;
                }
                w.write_all(b"\n    ")?;
                node_to_json(n).write_pretty_depth(w, 2)?;
            }
            w.write_all(b"\n  ]")?;
        }
        w.write_all(b"\n}")?;
        Ok(())
    }

    /// Convert to the internal graph representation and validate.
    pub fn to_graph(&self) -> Result<Graph> {
        let mut g = Graph::new(self.name.clone());
        let mut edge_by_name: HashMap<&str, EdgeId> = HashMap::new();

        for t in &self.tensors {
            let kind = if t.initializer {
                EdgeKind::Parameter
            } else {
                EdgeKind::Activation
            };
            let spec = TensorSpec::new(
                t.dims.clone(),
                ElemType {
                    bits: t.bits,
                    signed: t.signed,
                },
            );
            let id = g.add_edge(t.name.clone(), spec, kind);
            edge_by_name.insert(t.name.as_str(), id);
        }

        for gi in &self.graph_inputs {
            let e = *edge_by_name.get(gi.as_str()).ok_or_else(|| AladinError::Validation {
                at: gi.clone(),
                reason: "graph input tensor not declared".into(),
            })?;
            let n = g.add_node(format!("input_{gi}"), Op::Input);
            g.connect_output(n, e);
        }

        for qn in &self.nodes {
            let op = self.parse_op(qn, &g, &edge_by_name)?;
            let node = g.add_node(qn.name.clone(), op);
            for inp in &qn.inputs {
                let e = *edge_by_name.get(inp.as_str()).ok_or_else(|| {
                    AladinError::Validation {
                        at: qn.name.clone(),
                        reason: format!("unknown input tensor `{inp}`"),
                    }
                })?;
                g.connect_input(node, e);
            }
            for out in &qn.outputs {
                let e = *edge_by_name.get(out.as_str()).ok_or_else(|| {
                    AladinError::Validation {
                        at: qn.name.clone(),
                        reason: format!("unknown output tensor `{out}`"),
                    }
                })?;
                g.connect_output(node, e);
            }
        }

        for go in &self.graph_outputs {
            let e = *edge_by_name.get(go.as_str()).ok_or_else(|| AladinError::Validation {
                at: go.clone(),
                reason: "graph output tensor not declared".into(),
            })?;
            let n = g.add_node(format!("output_{go}"), Op::Output);
            g.connect_input(n, e);
        }

        validate::validate(&g)?;
        Ok(g)
    }

    fn parse_op(
        &self,
        n: &QonnxNode,
        g: &Graph,
        edges: &HashMap<&str, EdgeId>,
    ) -> Result<Op> {
        match n.op_type.as_str() {
            "Conv" => {
                let kernel = attr_pair(n, "kernel_shape").unwrap_or((3, 3));
                let stride = attr_pair(n, "strides").unwrap_or((1, 1));
                let padding = attr_pair(n, "pads").unwrap_or((0, 0));
                let groups = attr_usize(n, "group").unwrap_or(1);
                // out_channels from the weight initializer's first dim
                let w = n.inputs.get(1).and_then(|w| edges.get(w.as_str()));
                let out_channels = match w {
                    Some(&e) => g.edge(e).spec.dims[0],
                    None => attr_usize(n, "out_channels").ok_or_else(|| {
                        AladinError::Validation {
                            at: n.name.clone(),
                            reason: "Conv needs a weight tensor or out_channels attr".into(),
                        }
                    })?,
                };
                Ok(Op::Conv(ConvAttrs {
                    out_channels,
                    kernel,
                    stride,
                    padding,
                    groups,
                }))
            }
            "Gemm" | "MatMul" if n.inputs.len() >= 2 => {
                let w = edges
                    .get(n.inputs[1].as_str())
                    .ok_or_else(|| AladinError::Validation {
                        at: n.name.clone(),
                        reason: "Gemm weight tensor missing".into(),
                    })?;
                Ok(Op::Gemm(GemmAttrs {
                    out_features: g.edge(*w).spec.dims[0],
                }))
            }
            "Quant" => {
                let bits = attr_usize(n, "bits").unwrap_or(8) as u8;
                let signed = n
                    .attributes
                    .get("signed")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(true);
                let channelwise = n
                    .attributes
                    .get("channelwise")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false);
                Ok(Op::Quant(QuantAttrs {
                    to: ElemType { bits, signed },
                    channelwise,
                }))
            }
            "Relu" => Ok(Op::Relu),
            "Add" => Ok(Op::Add),
            "Flatten" | "Reshape" => Ok(Op::Flatten),
            "MaxPool" => Ok(Op::MaxPool(pool_attrs(n))),
            "AveragePool" | "GlobalAveragePool" => Ok(Op::AvgPool(pool_attrs(n))),
            other => Err(AladinError::Unsupported(format!(
                "QONNX op `{other}` (node `{}`)",
                n.name
            ))),
        }
    }
}

fn pool_attrs(n: &QonnxNode) -> PoolAttrs {
    let kernel = attr_pair(n, "kernel_shape").unwrap_or((2, 2));
    PoolAttrs {
        kernel,
        stride: attr_pair(n, "strides").unwrap_or(kernel),
        padding: attr_pair(n, "pads").unwrap_or((0, 0)),
    }
}

/// Export an internal graph back to the QONNX-dialect document.
pub fn export(g: &Graph) -> QonnxModel {
    let tensors = g
        .edges
        .iter()
        .map(|e| QonnxTensor {
            name: e.name.clone(),
            dims: e.spec.dims.clone(),
            bits: e.spec.elem.bits,
            signed: e.spec.elem.signed,
            initializer: e.is_param(),
            // internal graphs carry shapes/precisions only, never payloads
            data: None,
        })
        .collect();

    let mut nodes = Vec::new();
    let mut graph_inputs = Vec::new();
    let mut graph_outputs = Vec::new();
    for n in &g.nodes {
        match &n.op {
            Op::Input => {
                for e in &n.outputs {
                    graph_inputs.push(g.edge(*e).name.clone());
                }
            }
            Op::Output => {
                for e in &n.inputs {
                    graph_outputs.push(g.edge(*e).name.clone());
                }
            }
            op => {
                let mut attributes = HashMap::new();
                let op_type = match op {
                    Op::Conv(a) => {
                        attributes.insert(
                            "kernel_shape".into(),
                            Value::from(vec![a.kernel.0, a.kernel.1]),
                        );
                        attributes.insert(
                            "strides".into(),
                            Value::from(vec![a.stride.0, a.stride.1]),
                        );
                        attributes.insert(
                            "pads".into(),
                            Value::from(vec![a.padding.0, a.padding.1]),
                        );
                        attributes.insert("group".into(), Value::from(a.groups));
                        "Conv"
                    }
                    Op::Gemm(_) => "Gemm",
                    Op::MatMul(_) => "MatMul",
                    Op::Quant(a) => {
                        attributes.insert("bits".into(), Value::from(a.to.bits));
                        attributes.insert("signed".into(), Value::from(a.to.signed));
                        attributes
                            .insert("channelwise".into(), Value::from(a.channelwise));
                        "Quant"
                    }
                    Op::Relu => "Relu",
                    Op::Add => "Add",
                    Op::Flatten => "Flatten",
                    Op::MaxPool(a) => {
                        attributes.insert(
                            "kernel_shape".into(),
                            Value::from(vec![a.kernel.0, a.kernel.1]),
                        );
                        attributes.insert(
                            "strides".into(),
                            Value::from(vec![a.stride.0, a.stride.1]),
                        );
                        "MaxPool"
                    }
                    Op::AvgPool(a) => {
                        attributes.insert(
                            "kernel_shape".into(),
                            Value::from(vec![a.kernel.0, a.kernel.1]),
                        );
                        "AveragePool"
                    }
                    Op::Input | Op::Output => unreachable!(),
                };
                nodes.push(QonnxNode {
                    name: n.name.clone(),
                    op_type: op_type.to_string(),
                    inputs: n.inputs.iter().map(|e| g.edge(*e).name.clone()).collect(),
                    outputs: n.outputs.iter().map(|e| g.edge(*e).name.clone()).collect(),
                    attributes,
                });
            }
        }
    }

    QonnxModel {
        name: g.name.clone(),
        graph_inputs,
        graph_outputs,
        tensors,
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn sample() -> Graph {
        let mut b = GraphBuilder::new(
            "qx",
            TensorSpec::chw(3, 8, 8, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(4, 3, 1, 1), ElemType::int(4))
            .relu("r0")
            .quant("q0", ElemType::int(4), true)
            .flatten("f")
            .gemm("fc", 10, ElemType::int(8));
        b.finish()
    }

    #[test]
    fn export_import_round_trip() {
        let g = sample();
        let doc = export(&g);
        let g2 = doc.to_graph().unwrap();
        assert_eq!(g2.nodes.len(), g.nodes.len());
        assert_eq!(g2.edges.len(), g.edges.len());
        // op kinds preserved in order
        for (a, b) in g.nodes.iter().zip(g2.nodes.iter()) {
            assert_eq!(a.op.kind(), b.op.kind(), "node {}", a.name);
        }
        // quant precision preserved
        let q = g2.nodes.iter().find(|n| n.name == "q0").unwrap();
        if let Op::Quant(a) = &q.op {
            assert_eq!(a.to, ElemType::int(4));
            assert!(a.channelwise);
        } else {
            panic!("q0 not Quant");
        }
    }

    #[test]
    fn file_round_trip() {
        let g = sample();
        let doc = export(&g);
        let dir = crate::util::tempdir::tempdir().unwrap();
        let path = dir.path().join("model.qonnx.json");
        doc.to_file(&path).unwrap();
        let doc2 = QonnxModel::from_file(&path).unwrap();
        assert_eq!(doc2.nodes.len(), doc.nodes.len());
        doc2.to_graph().unwrap();
    }

    #[test]
    fn streamed_pretty_writer_matches_dom_serializer() {
        let mut doc = export(&sample());
        // exercise escapes and a data payload so the identity is not
        // trivially about the shape-only subset
        doc.name = "q\"x\\ tab\t".into();
        doc.tensors[0].data = Some(TensorData::Inline(vec![-3, 0, 127]));
        doc.tensors[0].dims = vec![3];
        let mut streamed = Vec::new();
        doc.write_pretty(&mut streamed).unwrap();
        assert_eq!(
            String::from_utf8(streamed).unwrap(),
            doc.to_json().unwrap().to_string_pretty()
        );
    }

    #[test]
    fn empty_model_pretty_writer_matches() {
        let doc = QonnxModel {
            name: "empty".into(),
            graph_inputs: vec![],
            graph_outputs: vec![],
            tensors: vec![],
            nodes: vec![],
        };
        let mut streamed = Vec::new();
        doc.write_pretty(&mut streamed).unwrap();
        assert_eq!(
            String::from_utf8(streamed).unwrap(),
            doc.to_json().unwrap().to_string_pretty()
        );
    }

    #[test]
    fn lazy_payload_round_trips_through_file() {
        let g = sample();
        let mut doc = export(&g);
        let n: i64 = doc.tensors[1].dims.iter().product::<usize>() as i64;
        doc.tensors[1].data =
            Some(TensorData::Inline((0..n).map(|i| (i % 251) - 125).collect()));
        let dir = crate::util::tempdir::tempdir().unwrap();
        let path = dir.path().join("lazy.qonnx.json");
        doc.to_file(&path).unwrap();
        // from_file is the streaming path with lazy payload extraction
        let doc2 = QonnxModel::from_file(&path).unwrap();
        let reloaded = &doc2.tensors[1].data;
        assert!(reloaded.as_ref().unwrap().is_lazy());
        // semantic equality decodes the span on demand
        assert_eq!(doc2, doc);
        // and re-serializing materializes identical bytes
        assert_eq!(
            doc2.to_json().unwrap().to_string_pretty(),
            doc.to_json().unwrap().to_string_pretty()
        );
    }

    #[test]
    fn data_length_mismatch_rejected() {
        let mut doc = export(&sample());
        // 3 values against a 3x8x8 tensor: serialization doesn't police the
        // payload, decode does
        doc.tensors[0].data = Some(TensorData::Inline(vec![1, 2, 3]));
        let text = doc.to_json().unwrap().to_string_pretty();
        let parsed = Value::parse(&text).unwrap();
        assert!(QonnxModel::from_json(&parsed).is_err());
    }

    #[test]
    fn unknown_op_rejected() {
        let mut doc = export(&sample());
        doc.nodes[0].op_type = "Softmax".into();
        assert!(doc.to_graph().is_err());
    }

    #[test]
    fn missing_tensor_rejected() {
        let mut doc = export(&sample());
        doc.nodes[0].inputs[0] = "nope".into();
        assert!(doc.to_graph().is_err());
    }
}
