//! QONNX-dialect JSON import/export.
//!
//! The paper's workflow starts from a QONNX file (ONNX + arbitrary-precision
//! Quant nodes). We do not link against protobuf-ONNX; instead we define a
//! faithful JSON projection of the QONNX subset the paper uses (Quant, Conv,
//! Gemm, Relu, MaxPool/AvgPool, Flatten, Add) and convert it to/from the
//! internal [`Graph`]. `python/compile/export_qonnx.py` emits the same
//! dialect from the JAX model, closing the toolchain loop.

use super::ir::*;
use super::tensor::{ElemType, TensorSpec};
use super::validate;
use crate::error::{AladinError, Result};
use crate::util::json::Value;
use std::collections::HashMap;
use std::path::Path;

/// One node of the on-disk QONNX-dialect document.
#[derive(Debug, Clone)]
pub struct QonnxNode {
    /// Unique node name.
    pub name: String,
    /// Operator type: "Quant" | "Conv" | "Gemm" | "Relu" | "MaxPool"
    /// | "AveragePool" | "Flatten" | "Add".
    pub op_type: String,
    /// Names of input tensors (activations then initializers).
    pub inputs: Vec<String>,
    /// Names of output tensors.
    pub outputs: Vec<String>,
    /// Operator attributes (kernel_shape, strides, pads, group, bits, …).
    pub attributes: HashMap<String, Value>,
}

/// Tensor type declaration.
#[derive(Debug, Clone)]
pub struct QonnxTensor {
    /// Tensor name, referenced by node inputs/outputs.
    pub name: String,
    /// Dimensions, outermost first.
    pub dims: Vec<usize>,
    /// Bit-width of each element.
    pub bits: u8,
    /// Two's-complement signedness.
    pub signed: bool,
    /// True for constant initializers (weights, biases, thresholds).
    pub initializer: bool,
}

/// On-disk QONNX-dialect document.
#[derive(Debug, Clone)]
pub struct QonnxModel {
    /// Model name.
    pub name: String,
    /// Names of the graph's input tensors.
    pub graph_inputs: Vec<String>,
    /// Names of the graph's output tensors.
    pub graph_outputs: Vec<String>,
    /// All tensor declarations (activations and initializers).
    pub tensors: Vec<QonnxTensor>,
    /// Operation nodes in document order.
    pub nodes: Vec<QonnxNode>,
}

fn attr_usize(n: &QonnxNode, key: &str) -> Option<usize> {
    n.attributes.get(key).and_then(|v| v.as_u64()).map(|v| v as usize)
}

fn attr_pair(n: &QonnxNode, key: &str) -> Option<(usize, usize)> {
    let arr = n.attributes.get(key)?.as_arr()?;
    let a = arr.first()?.as_u64()? as usize;
    let b = arr.get(1).and_then(|v| v.as_u64()).unwrap_or(a as u64) as usize;
    Some((a, b))
}

impl QonnxModel {
    /// Read and parse a QONNX-dialect JSON file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Value::parse(&text)?)
    }

    /// Write the document as pretty-printed JSON.
    pub fn to_file(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Parse from the in-tree JSON document model.
    pub fn from_json(v: &Value) -> Result<Self> {
        let bad = |reason: &str| AladinError::Parse {
            at: "qonnx".into(),
            reason: reason.into(),
        };
        let strings = |key: &str| -> Result<Vec<String>> {
            v.get(key)
                .and_then(|a| a.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|s| s.as_str().map(String::from))
                        .collect()
                })
                .ok_or_else(|| bad(&format!("missing `{key}` array")))
        };
        let tensors = v
            .get("tensors")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| bad("missing `tensors`"))?
            .iter()
            .map(|t| {
                Ok(QonnxTensor {
                    name: t
                        .str_field("name")
                        .ok_or_else(|| bad("tensor missing name"))?
                        .to_string(),
                    dims: t
                        .get("dims")
                        .and_then(|d| d.as_arr())
                        .ok_or_else(|| bad("tensor missing dims"))?
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect(),
                    bits: t.u64_field("bits").ok_or_else(|| bad("tensor missing bits"))? as u8,
                    signed: t.bool_field("signed").unwrap_or(true),
                    initializer: t.bool_field("initializer").unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let nodes = v
            .get("nodes")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| bad("missing `nodes`"))?
            .iter()
            .map(|n| {
                let list = |key: &str| -> Vec<String> {
                    n.get(key)
                        .and_then(|a| a.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|s| s.as_str().map(String::from))
                                .collect()
                        })
                        .unwrap_or_default()
                };
                let attributes = n
                    .get("attributes")
                    .and_then(|o| o.as_obj())
                    .map(|pairs| pairs.iter().cloned().collect::<HashMap<_, _>>())
                    .unwrap_or_default();
                Ok(QonnxNode {
                    name: n
                        .str_field("name")
                        .ok_or_else(|| bad("node missing name"))?
                        .to_string(),
                    op_type: n
                        .str_field("op_type")
                        .ok_or_else(|| bad("node missing op_type"))?
                        .to_string(),
                    inputs: list("inputs"),
                    outputs: list("outputs"),
                    attributes,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(QonnxModel {
            name: v.str_field("name").unwrap_or("model").to_string(),
            graph_inputs: strings("graph_inputs")?,
            graph_outputs: strings("graph_outputs")?,
            tensors,
            nodes,
        })
    }

    /// Render to the in-tree JSON document model.
    pub fn to_json(&self) -> Value {
        let tensors: Vec<Value> = self
            .tensors
            .iter()
            .map(|t| {
                Value::obj()
                    .with("name", t.name.clone())
                    .with("dims", t.dims.clone())
                    .with("bits", t.bits)
                    .with("signed", t.signed)
                    .with("initializer", t.initializer)
            })
            .collect();
        let nodes: Vec<Value> = self
            .nodes
            .iter()
            .map(|n| {
                let mut attrs: Vec<(String, Value)> =
                    n.attributes.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
                attrs.sort_by(|a, b| a.0.cmp(&b.0));
                Value::obj()
                    .with("name", n.name.clone())
                    .with("op_type", n.op_type.clone())
                    .with("inputs", n.inputs.clone())
                    .with("outputs", n.outputs.clone())
                    .with("attributes", Value::Obj(attrs))
            })
            .collect();
        Value::obj()
            .with("name", self.name.clone())
            .with("graph_inputs", self.graph_inputs.clone())
            .with("graph_outputs", self.graph_outputs.clone())
            .with("tensors", Value::Arr(tensors))
            .with("nodes", Value::Arr(nodes))
    }

    /// Convert to the internal graph representation and validate.
    pub fn to_graph(&self) -> Result<Graph> {
        let mut g = Graph::new(self.name.clone());
        let mut edge_by_name: HashMap<&str, EdgeId> = HashMap::new();

        for t in &self.tensors {
            let kind = if t.initializer {
                EdgeKind::Parameter
            } else {
                EdgeKind::Activation
            };
            let spec = TensorSpec::new(
                t.dims.clone(),
                ElemType {
                    bits: t.bits,
                    signed: t.signed,
                },
            );
            let id = g.add_edge(t.name.clone(), spec, kind);
            edge_by_name.insert(t.name.as_str(), id);
        }

        for gi in &self.graph_inputs {
            let e = *edge_by_name.get(gi.as_str()).ok_or_else(|| AladinError::Validation {
                at: gi.clone(),
                reason: "graph input tensor not declared".into(),
            })?;
            let n = g.add_node(format!("input_{gi}"), Op::Input);
            g.connect_output(n, e);
        }

        for qn in &self.nodes {
            let op = self.parse_op(qn, &g, &edge_by_name)?;
            let node = g.add_node(qn.name.clone(), op);
            for inp in &qn.inputs {
                let e = *edge_by_name.get(inp.as_str()).ok_or_else(|| {
                    AladinError::Validation {
                        at: qn.name.clone(),
                        reason: format!("unknown input tensor `{inp}`"),
                    }
                })?;
                g.connect_input(node, e);
            }
            for out in &qn.outputs {
                let e = *edge_by_name.get(out.as_str()).ok_or_else(|| {
                    AladinError::Validation {
                        at: qn.name.clone(),
                        reason: format!("unknown output tensor `{out}`"),
                    }
                })?;
                g.connect_output(node, e);
            }
        }

        for go in &self.graph_outputs {
            let e = *edge_by_name.get(go.as_str()).ok_or_else(|| AladinError::Validation {
                at: go.clone(),
                reason: "graph output tensor not declared".into(),
            })?;
            let n = g.add_node(format!("output_{go}"), Op::Output);
            g.connect_input(n, e);
        }

        validate::validate(&g)?;
        Ok(g)
    }

    fn parse_op(
        &self,
        n: &QonnxNode,
        g: &Graph,
        edges: &HashMap<&str, EdgeId>,
    ) -> Result<Op> {
        match n.op_type.as_str() {
            "Conv" => {
                let kernel = attr_pair(n, "kernel_shape").unwrap_or((3, 3));
                let stride = attr_pair(n, "strides").unwrap_or((1, 1));
                let padding = attr_pair(n, "pads").unwrap_or((0, 0));
                let groups = attr_usize(n, "group").unwrap_or(1);
                // out_channels from the weight initializer's first dim
                let w = n.inputs.get(1).and_then(|w| edges.get(w.as_str()));
                let out_channels = match w {
                    Some(&e) => g.edge(e).spec.dims[0],
                    None => attr_usize(n, "out_channels").ok_or_else(|| {
                        AladinError::Validation {
                            at: n.name.clone(),
                            reason: "Conv needs a weight tensor or out_channels attr".into(),
                        }
                    })?,
                };
                Ok(Op::Conv(ConvAttrs {
                    out_channels,
                    kernel,
                    stride,
                    padding,
                    groups,
                }))
            }
            "Gemm" | "MatMul" if n.inputs.len() >= 2 => {
                let w = edges
                    .get(n.inputs[1].as_str())
                    .ok_or_else(|| AladinError::Validation {
                        at: n.name.clone(),
                        reason: "Gemm weight tensor missing".into(),
                    })?;
                Ok(Op::Gemm(GemmAttrs {
                    out_features: g.edge(*w).spec.dims[0],
                }))
            }
            "Quant" => {
                let bits = attr_usize(n, "bits").unwrap_or(8) as u8;
                let signed = n
                    .attributes
                    .get("signed")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(true);
                let channelwise = n
                    .attributes
                    .get("channelwise")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false);
                Ok(Op::Quant(QuantAttrs {
                    to: ElemType { bits, signed },
                    channelwise,
                }))
            }
            "Relu" => Ok(Op::Relu),
            "Add" => Ok(Op::Add),
            "Flatten" | "Reshape" => Ok(Op::Flatten),
            "MaxPool" => Ok(Op::MaxPool(pool_attrs(n))),
            "AveragePool" | "GlobalAveragePool" => Ok(Op::AvgPool(pool_attrs(n))),
            other => Err(AladinError::Unsupported(format!(
                "QONNX op `{other}` (node `{}`)",
                n.name
            ))),
        }
    }
}

fn pool_attrs(n: &QonnxNode) -> PoolAttrs {
    let kernel = attr_pair(n, "kernel_shape").unwrap_or((2, 2));
    PoolAttrs {
        kernel,
        stride: attr_pair(n, "strides").unwrap_or(kernel),
        padding: attr_pair(n, "pads").unwrap_or((0, 0)),
    }
}

/// Export an internal graph back to the QONNX-dialect document.
pub fn export(g: &Graph) -> QonnxModel {
    let tensors = g
        .edges
        .iter()
        .map(|e| QonnxTensor {
            name: e.name.clone(),
            dims: e.spec.dims.clone(),
            bits: e.spec.elem.bits,
            signed: e.spec.elem.signed,
            initializer: e.is_param(),
        })
        .collect();

    let mut nodes = Vec::new();
    let mut graph_inputs = Vec::new();
    let mut graph_outputs = Vec::new();
    for n in &g.nodes {
        match &n.op {
            Op::Input => {
                for e in &n.outputs {
                    graph_inputs.push(g.edge(*e).name.clone());
                }
            }
            Op::Output => {
                for e in &n.inputs {
                    graph_outputs.push(g.edge(*e).name.clone());
                }
            }
            op => {
                let mut attributes = HashMap::new();
                let op_type = match op {
                    Op::Conv(a) => {
                        attributes.insert(
                            "kernel_shape".into(),
                            Value::from(vec![a.kernel.0, a.kernel.1]),
                        );
                        attributes.insert(
                            "strides".into(),
                            Value::from(vec![a.stride.0, a.stride.1]),
                        );
                        attributes.insert(
                            "pads".into(),
                            Value::from(vec![a.padding.0, a.padding.1]),
                        );
                        attributes.insert("group".into(), Value::from(a.groups));
                        "Conv"
                    }
                    Op::Gemm(_) => "Gemm",
                    Op::MatMul(_) => "MatMul",
                    Op::Quant(a) => {
                        attributes.insert("bits".into(), Value::from(a.to.bits));
                        attributes.insert("signed".into(), Value::from(a.to.signed));
                        attributes
                            .insert("channelwise".into(), Value::from(a.channelwise));
                        "Quant"
                    }
                    Op::Relu => "Relu",
                    Op::Add => "Add",
                    Op::Flatten => "Flatten",
                    Op::MaxPool(a) => {
                        attributes.insert(
                            "kernel_shape".into(),
                            Value::from(vec![a.kernel.0, a.kernel.1]),
                        );
                        attributes.insert(
                            "strides".into(),
                            Value::from(vec![a.stride.0, a.stride.1]),
                        );
                        "MaxPool"
                    }
                    Op::AvgPool(a) => {
                        attributes.insert(
                            "kernel_shape".into(),
                            Value::from(vec![a.kernel.0, a.kernel.1]),
                        );
                        "AveragePool"
                    }
                    Op::Input | Op::Output => unreachable!(),
                };
                nodes.push(QonnxNode {
                    name: n.name.clone(),
                    op_type: op_type.to_string(),
                    inputs: n.inputs.iter().map(|e| g.edge(*e).name.clone()).collect(),
                    outputs: n.outputs.iter().map(|e| g.edge(*e).name.clone()).collect(),
                    attributes,
                });
            }
        }
    }

    QonnxModel {
        name: g.name.clone(),
        graph_inputs,
        graph_outputs,
        tensors,
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn sample() -> Graph {
        let mut b = GraphBuilder::new(
            "qx",
            TensorSpec::chw(3, 8, 8, ElemType::int(8)),
            ElemType::int(32),
        );
        b.conv("c0", ConvAttrs::standard(4, 3, 1, 1), ElemType::int(4))
            .relu("r0")
            .quant("q0", ElemType::int(4), true)
            .flatten("f")
            .gemm("fc", 10, ElemType::int(8));
        b.finish()
    }

    #[test]
    fn export_import_round_trip() {
        let g = sample();
        let doc = export(&g);
        let g2 = doc.to_graph().unwrap();
        assert_eq!(g2.nodes.len(), g.nodes.len());
        assert_eq!(g2.edges.len(), g.edges.len());
        // op kinds preserved in order
        for (a, b) in g.nodes.iter().zip(g2.nodes.iter()) {
            assert_eq!(a.op.kind(), b.op.kind(), "node {}", a.name);
        }
        // quant precision preserved
        let q = g2.nodes.iter().find(|n| n.name == "q0").unwrap();
        if let Op::Quant(a) = &q.op {
            assert_eq!(a.to, ElemType::int(4));
            assert!(a.channelwise);
        } else {
            panic!("q0 not Quant");
        }
    }

    #[test]
    fn file_round_trip() {
        let g = sample();
        let doc = export(&g);
        let dir = crate::util::tempdir::tempdir().unwrap();
        let path = dir.path().join("model.qonnx.json");
        doc.to_file(&path).unwrap();
        let doc2 = QonnxModel::from_file(&path).unwrap();
        assert_eq!(doc2.nodes.len(), doc.nodes.len());
        doc2.to_graph().unwrap();
    }

    #[test]
    fn unknown_op_rejected() {
        let mut doc = export(&sample());
        doc.nodes[0].op_type = "Softmax".into();
        assert!(doc.to_graph().is_err());
    }

    #[test]
    fn missing_tensor_rejected() {
        let mut doc = export(&sample());
        doc.nodes[0].inputs[0] = "nope".into();
        assert!(doc.to_graph().is_err());
    }
}
