//! Streaming QONNX ingest: event-driven decoding of production-size
//! documents without a DOM tree.
//!
//! [`QonnxModel::from_json`] decodes a parsed [`Value`] — which means a
//! ResNet-50-class file with hundreds of MB of initializer payload first
//! materializes hundreds of millions of `Value` nodes. This module
//! decodes the same dialect straight from the
//! [`pull`](crate::util::json::pull) event stream: tensors, nodes and
//! attributes are built as events arrive, and initializer `data` arrays
//! are handled per [`DataPolicy`] — recorded as byte spans (`Lazy`),
//! decoded in place (`Eager`), or dropped (`Skip`). The analyze/eval/DSE
//! flows never read weight payloads, so the default file ingest
//! ([`QonnxModel::from_file`]) uses `Lazy` and the parse cost of the
//! payload collapses to a structural skip.
//!
//! Decode semantics are identical to the DOM path — same required
//! fields, same defaults, same rejection of mistyped entries and
//! duplicate keys — and `tests/qonnx_stream.rs` holds the two paths
//! bit-identical over a randomized document corpus. One documented
//! exception: regions this decoder *skips* (unknown keys, lazy payloads)
//! are validated structurally but not re-checked for duplicate keys or
//! UTF-8, exactly the deferral that makes lazy ingest cheap.

use super::qonnx::{
    check_data_len, num_to_i64, parse_err, QonnxModel, QonnxNode, QonnxTensor, TensorData,
};
use crate::error::Result;
use crate::util::json::pull::{Event, PullParser};
use crate::util::json::{pull, Value};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// What to do with initializer `data` payloads during ingest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPolicy {
    /// Decode payloads into [`TensorData::Inline`] as they stream past.
    Eager,
    /// Record payloads as [`TensorData::Lazy`] byte spans into the shared
    /// source buffer; decode happens on first access (if ever).
    Lazy,
    /// Drop payloads entirely (`data: None`) — the cheapest ingest for
    /// flows that only need shapes, precisions and topology.
    Skip,
}

/// Read and decode a QONNX-dialect JSON file with the given policy.
pub fn from_file(path: impl AsRef<Path>, policy: DataPolicy) -> Result<QonnxModel> {
    from_bytes(std::fs::read(path)?, policy)
}

/// Decode an owned document buffer. For [`DataPolicy::Lazy`] the buffer
/// is moved (not copied) into the shared `Arc` that lazy spans index.
pub fn from_bytes(bytes: Vec<u8>, policy: DataPolicy) -> Result<QonnxModel> {
    let source = Arc::new(bytes);
    parse_model(&source, policy, Some(&source))
}

/// Decode a borrowed document window. [`DataPolicy::Lazy`] needs an owned
/// source for its spans to outlive the call, so that policy copies the
/// window once; `Eager`/`Skip` decode in place with no copy.
pub fn from_slice(bytes: &[u8], policy: DataPolicy) -> Result<QonnxModel> {
    if policy == DataPolicy::Lazy {
        from_bytes(bytes.to_vec(), policy)
    } else {
        parse_model(bytes, policy, None)
    }
}

/// Top-level document fields (anything else is skipped).
enum Field {
    Name,
    GraphInputs,
    GraphOutputs,
    Tensors,
    Nodes,
    Other,
}

impl Field {
    fn of(key: &str) -> Field {
        match key {
            "name" => Field::Name,
            "graph_inputs" => Field::GraphInputs,
            "graph_outputs" => Field::GraphOutputs,
            "tensors" => Field::Tensors,
            "nodes" => Field::Nodes,
            _ => Field::Other,
        }
    }
}

fn no_dup<T>(slot: &Option<T>, key: &str) -> Result<()> {
    if slot.is_some() {
        Err(parse_err(format!("duplicate key `{key}`")))
    } else {
        Ok(())
    }
}

fn parse_model(
    bytes: &[u8],
    policy: DataPolicy,
    source: Option<&Arc<Vec<u8>>>,
) -> Result<QonnxModel> {
    let mut p = PullParser::new(bytes);
    if p.next_event()? != Event::BeginObject {
        return Err(parse_err("expected a QONNX document object"));
    }
    let mut name: Option<String> = None;
    let mut graph_inputs: Option<Vec<String>> = None;
    let mut graph_outputs: Option<Vec<String>> = None;
    let mut tensors: Option<Vec<QonnxTensor>> = None;
    let mut nodes: Option<Vec<QonnxNode>> = None;
    loop {
        let field = match p.next_event()? {
            Event::Key(k) => Field::of(k),
            Event::EndObject => break,
            _ => return Err(parse_err("malformed document object")),
        };
        match field {
            Field::Name => {
                no_dup(&name, "name")?;
                name = Some(expect_str(&mut p, "`name` must be a string")?);
            }
            Field::GraphInputs => {
                no_dup(&graph_inputs, "graph_inputs")?;
                graph_inputs = Some(read_string_array(&mut p, "graph_inputs")?);
            }
            Field::GraphOutputs => {
                no_dup(&graph_outputs, "graph_outputs")?;
                graph_outputs = Some(read_string_array(&mut p, "graph_outputs")?);
            }
            Field::Tensors => {
                no_dup(&tensors, "tensors")?;
                tensors = Some(read_tensors(&mut p, policy, source)?);
            }
            Field::Nodes => {
                no_dup(&nodes, "nodes")?;
                nodes = Some(read_nodes(&mut p)?);
            }
            Field::Other => {
                p.skip_value()?;
            }
        }
    }
    // only trailing whitespace may remain
    if p.next_event()? != Event::End {
        return Err(parse_err("trailing characters"));
    }
    Ok(QonnxModel {
        name: name.unwrap_or_else(|| "model".to_string()),
        graph_inputs: graph_inputs.ok_or_else(|| parse_err("missing `graph_inputs` array"))?,
        graph_outputs: graph_outputs.ok_or_else(|| parse_err("missing `graph_outputs` array"))?,
        tensors: tensors.ok_or_else(|| parse_err("missing `tensors`"))?,
        nodes: nodes.ok_or_else(|| parse_err("missing `nodes`"))?,
    })
}

fn expect_str(p: &mut PullParser<'_>, msg: &str) -> Result<String> {
    match p.next_event()? {
        Event::Str(s) => Ok(s.to_string()),
        _ => Err(parse_err(msg)),
    }
}

fn expect_bool(p: &mut PullParser<'_>, msg: &str) -> Result<bool> {
    match p.next_event()? {
        Event::Bool(b) => Ok(b),
        _ => Err(parse_err(msg)),
    }
}

fn read_string_array(p: &mut PullParser<'_>, key: &str) -> Result<Vec<String>> {
    if p.next_event()? != Event::BeginArray {
        return Err(parse_err(format!("missing `{key}` array")));
    }
    let mut out = Vec::new();
    loop {
        let item = match p.next_event()? {
            Event::Str(s) => Some(s.to_string()),
            Event::EndArray => None,
            _ => return Err(parse_err(format!("`{key}` entries must be strings"))),
        };
        match item {
            Some(s) => out.push(s),
            None => return Ok(out),
        }
    }
}

// ---- tensors ----------------------------------------------------------------

/// Tensor object fields (anything else is skipped).
enum TField {
    Name,
    Dims,
    Bits,
    Signed,
    Initializer,
    Data,
    Other,
}

impl TField {
    fn of(key: &str) -> TField {
        match key {
            "name" => TField::Name,
            "dims" => TField::Dims,
            "bits" => TField::Bits,
            "signed" => TField::Signed,
            "initializer" => TField::Initializer,
            "data" => TField::Data,
            _ => TField::Other,
        }
    }
}

fn read_tensors(
    p: &mut PullParser<'_>,
    policy: DataPolicy,
    source: Option<&Arc<Vec<u8>>>,
) -> Result<Vec<QonnxTensor>> {
    if p.next_event()? != Event::BeginArray {
        return Err(parse_err("missing `tensors`"));
    }
    let mut out = Vec::new();
    loop {
        match p.next_event()? {
            Event::BeginObject => {}
            Event::EndArray => return Ok(out),
            _ => return Err(parse_err("tensor entries must be objects")),
        }
        out.push(read_tensor(p, policy, source)?);
    }
}

fn read_tensor(
    p: &mut PullParser<'_>,
    policy: DataPolicy,
    source: Option<&Arc<Vec<u8>>>,
) -> Result<QonnxTensor> {
    let mut name: Option<String> = None;
    let mut dims: Option<Vec<usize>> = None;
    let mut bits: Option<u64> = None;
    let mut signed: Option<bool> = None;
    let mut initializer: Option<bool> = None;
    let mut data: Option<TensorData> = None;
    let mut data_seen = false;
    loop {
        let field = match p.next_event()? {
            Event::Key(k) => TField::of(k),
            Event::EndObject => break,
            _ => return Err(parse_err("malformed tensor object")),
        };
        match field {
            TField::Name => {
                no_dup(&name, "name")?;
                name = Some(expect_str(p, "tensor missing name")?);
            }
            TField::Dims => {
                no_dup(&dims, "dims")?;
                dims = Some(read_dims(p)?);
            }
            TField::Bits => {
                no_dup(&bits, "bits")?;
                let b = match p.next_event()? {
                    Event::Num(n) if n >= 0.0 && n.fract() == 0.0 => n as u64,
                    _ => return Err(parse_err("tensor missing bits")),
                };
                if b == 0 || b > u64::from(u8::MAX) {
                    return Err(parse_err(format!("tensor bits {b} out of range 1..=255")));
                }
                bits = Some(b);
            }
            TField::Signed => {
                no_dup(&signed, "signed")?;
                signed = Some(expect_bool(p, "tensor signed must be a boolean")?);
            }
            TField::Initializer => {
                no_dup(&initializer, "initializer")?;
                initializer = Some(expect_bool(p, "tensor initializer must be a boolean")?);
            }
            TField::Data => {
                if data_seen {
                    return Err(parse_err("duplicate key `data`"));
                }
                data_seen = true;
                data = match policy {
                    DataPolicy::Skip => {
                        p.skip_value()?;
                        None
                    }
                    DataPolicy::Lazy => {
                        let span = p.skip_value()?;
                        let src = source
                            .ok_or_else(|| parse_err("lazy ingest requires an owned source"))?;
                        Some(TensorData::Lazy {
                            span,
                            source: src.clone(),
                        })
                    }
                    DataPolicy::Eager => Some(TensorData::Inline(read_data_eager(p)?)),
                };
            }
            TField::Other => {
                p.skip_value()?;
            }
        }
    }
    let name = name.ok_or_else(|| parse_err("tensor missing name"))?;
    let dims = dims.ok_or_else(|| parse_err(format!("tensor `{name}` missing dims")))?;
    let bits = bits.ok_or_else(|| parse_err(format!("tensor `{name}` missing bits")))?;
    if let Some(TensorData::Inline(vals)) = &data {
        check_data_len(&name, &dims, vals.len())?;
    }
    Ok(QonnxTensor {
        name,
        dims,
        bits: bits as u8,
        signed: signed.unwrap_or(true),
        initializer: initializer.unwrap_or(false),
        data,
    })
}

fn read_dims(p: &mut PullParser<'_>) -> Result<Vec<usize>> {
    if p.next_event()? != Event::BeginArray {
        return Err(parse_err("tensor missing dims"));
    }
    let mut out = Vec::new();
    loop {
        match p.next_event()? {
            // mirror of `Value::as_usize`: non-negative integers only
            Event::Num(n) if n >= 0.0 && n.fract() == 0.0 => out.push(n as u64 as usize),
            Event::EndArray => return Ok(out),
            _ => {
                return Err(parse_err("tensor dims entries must be non-negative integers"));
            }
        }
    }
}

fn read_data_eager(p: &mut PullParser<'_>) -> Result<Vec<i64>> {
    if p.next_event()? != Event::BeginArray {
        return Err(parse_err("tensor data must be an array of integers"));
    }
    let mut out = Vec::new();
    loop {
        match p.next_event()? {
            Event::Num(n) => out.push(num_to_i64(n)?),
            Event::EndArray => return Ok(out),
            _ => return Err(parse_err("tensor data entries must be integers")),
        }
    }
}

// ---- nodes ------------------------------------------------------------------

/// Node object fields (anything else is skipped).
enum NField {
    Name,
    OpType,
    Inputs,
    Outputs,
    Attributes,
    Other,
}

impl NField {
    fn of(key: &str) -> NField {
        match key {
            "name" => NField::Name,
            "op_type" => NField::OpType,
            "inputs" => NField::Inputs,
            "outputs" => NField::Outputs,
            "attributes" => NField::Attributes,
            _ => NField::Other,
        }
    }
}

fn read_nodes(p: &mut PullParser<'_>) -> Result<Vec<QonnxNode>> {
    if p.next_event()? != Event::BeginArray {
        return Err(parse_err("missing `nodes`"));
    }
    let mut out = Vec::new();
    loop {
        match p.next_event()? {
            Event::BeginObject => {}
            Event::EndArray => return Ok(out),
            _ => return Err(parse_err("node entries must be objects")),
        }
        out.push(read_node(p)?);
    }
}

fn read_node(p: &mut PullParser<'_>) -> Result<QonnxNode> {
    let mut name: Option<String> = None;
    let mut op_type: Option<String> = None;
    let mut inputs: Option<Vec<String>> = None;
    let mut outputs: Option<Vec<String>> = None;
    let mut attributes: Option<HashMap<String, Value>> = None;
    loop {
        let field = match p.next_event()? {
            Event::Key(k) => NField::of(k),
            Event::EndObject => break,
            _ => return Err(parse_err("malformed node object")),
        };
        match field {
            NField::Name => {
                no_dup(&name, "name")?;
                name = Some(expect_str(p, "node missing name")?);
            }
            NField::OpType => {
                no_dup(&op_type, "op_type")?;
                op_type = Some(expect_str(p, "node missing op_type")?);
            }
            NField::Inputs => {
                no_dup(&inputs, "inputs")?;
                inputs = Some(read_string_array(p, "inputs")?);
            }
            NField::Outputs => {
                no_dup(&outputs, "outputs")?;
                outputs = Some(read_string_array(p, "outputs")?);
            }
            NField::Attributes => {
                no_dup(&attributes, "attributes")?;
                attributes = Some(read_attributes(p)?);
            }
            NField::Other => {
                p.skip_value()?;
            }
        }
    }
    Ok(QonnxNode {
        name: name.ok_or_else(|| parse_err("node missing name"))?,
        op_type: op_type.ok_or_else(|| parse_err("node missing op_type"))?,
        inputs: inputs.unwrap_or_default(),
        outputs: outputs.unwrap_or_default(),
        attributes: attributes.unwrap_or_default(),
    })
}

fn read_attributes(p: &mut PullParser<'_>) -> Result<HashMap<String, Value>> {
    if p.next_event()? != Event::BeginObject {
        return Err(parse_err("node attributes must be an object"));
    }
    let mut map = HashMap::new();
    loop {
        let key = match p.next_event()? {
            Event::Key(k) => Some(k.to_string()),
            Event::EndObject => None,
            _ => return Err(parse_err("malformed attributes object")),
        };
        let Some(key) = key else {
            return Ok(map);
        };
        // attribute values are small islands in a big document: rebuild
        // them as DOM values so downstream op parsing stays unchanged
        let v = pull::read_value(p)?;
        if map.insert(key.clone(), v).is_some() {
            return Err(parse_err(format!("duplicate key `{key}`")));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "name": "tiny",
      "future_proof": {"ignored": [1, 2, {"deep": true}]},
      "graph_inputs": ["in"],
      "graph_outputs": ["out"],
      "tensors": [
        {"name": "in", "dims": [1, 4], "bits": 8},
        {"name": "w", "dims": [2, 4], "bits": 4, "signed": true,
         "initializer": true, "data": [1, -2, 3, -4, 5, -6, 7, -8]},
        {"name": "out", "dims": [1, 2], "bits": 32, "signed": true}
      ],
      "nodes": [
        {"name": "fc", "op_type": "Gemm", "inputs": ["in", "w"],
         "outputs": ["out"], "attributes": {"alpha": 1.0, "note": "a\nb"}}
      ]
    }"#;

    #[test]
    fn streaming_matches_dom_on_sample() {
        let dom = QonnxModel::from_json(&Value::parse(DOC).unwrap()).unwrap();
        let eager = from_slice(DOC.as_bytes(), DataPolicy::Eager).unwrap();
        assert_eq!(dom, eager);
        // lazy differs only in payload representation, compares equal
        let lazy = from_slice(DOC.as_bytes(), DataPolicy::Lazy).unwrap();
        assert!(lazy.tensors[1].data.as_ref().unwrap().is_lazy());
        assert_eq!(dom, lazy);
    }

    #[test]
    fn skip_policy_drops_payloads() {
        let skipped = from_slice(DOC.as_bytes(), DataPolicy::Skip).unwrap();
        assert!(skipped.tensors[1].data.is_none());
        // everything else survives
        assert_eq!(skipped.nodes.len(), 1);
        assert_eq!(skipped.tensors.len(), 3);
        assert_eq!(
            skipped.nodes[0].attributes.get("note").unwrap().as_str(),
            Some("a\nb")
        );
    }

    #[test]
    fn lazy_payload_decodes_on_demand() {
        let lazy = from_slice(DOC.as_bytes(), DataPolicy::Lazy).unwrap();
        let data = lazy.tensors[1].data.as_ref().unwrap();
        assert_eq!(
            data.values().unwrap().as_ref(),
            &[1, -2, 3, -4, 5, -6, 7, -8]
        );
    }

    #[test]
    fn streamed_model_drives_the_analyze_entry() {
        let model = from_slice(DOC.as_bytes(), DataPolicy::Lazy).unwrap();
        model.to_graph().unwrap();
    }

    #[test]
    fn malformed_documents_error_on_both_paths() {
        let cases = [
            r#"{"name": "x"}"#,                       // missing sections
            r#"{"graph_inputs": [1]}"#,               // non-string entries
            r#"{"graph_inputs": ["a"], "graph_outputs": [], "tensors": [{"name": "t", "dims": [2], "bits": 8, "data": [1]}], "nodes": []}"#, // length mismatch
            r#"{"graph_inputs": ["a"], "graph_outputs": [], "tensors": [{"name": "t", "dims": [1], "bits": 300}], "nodes": []}"#, // bits out of range
            r#"{"graph_inputs": ["a"], "graph_outputs": [], "tensors": [{"name": "t", "dims": [1], "bits": 8, "data": [1.5]}], "nodes": []}"#, // fractional data
            r#"{"tensors": [], "tensors": []}"#,      // duplicate key
        ];
        for doc in cases {
            let dom = Value::parse(doc).map(|v| QonnxModel::from_json(&v));
            let dom_ok = matches!(dom, Ok(Ok(_)));
            assert!(!dom_ok, "DOM accepted: {doc}");
            assert!(
                from_slice(doc.as_bytes(), DataPolicy::Eager).is_err(),
                "stream accepted: {doc}"
            );
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let doc = format!("{DOC} extra");
        assert!(from_slice(doc.as_bytes(), DataPolicy::Eager).is_err());
    }
}
