//! Tensor specifications carried on DAG edges.
//!
//! In the QONNX-style representation of the paper (§IV-B), data flowing
//! between operations is a tensor `<x_1, …, x_n>_b` where `b` is the
//! bit-width of each element. We additionally track signedness, which
//! determines the representable integer range used by quantizers and by
//! the threshold-tree construction.

use std::fmt;

/// Integer element type: a bit-width plus signedness.
///
/// Bit-widths are arbitrary (QONNX-style), not restricted to powers of two;
/// the platform-aware refinement decides how sub-byte values are packed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ElemType {
    /// Number of bits per element (1..=32).
    pub bits: u8,
    /// Whether the integer representation is signed (two's complement).
    pub signed: bool,
}

impl ElemType {
    /// Signed integer of `bits` bits (e.g. `int8`, `int4`).
    pub const fn int(bits: u8) -> Self {
        Self { bits, signed: true }
    }

    /// Unsigned integer of `bits` bits (e.g. `uint8`).
    pub const fn uint(bits: u8) -> Self {
        Self { bits, signed: false }
    }

    /// Smallest representable value.
    pub fn min_value(&self) -> i64 {
        if self.signed {
            -(1i64 << (self.bits - 1))
        } else {
            0
        }
    }

    /// Largest representable value.
    pub fn max_value(&self) -> i64 {
        if self.signed {
            (1i64 << (self.bits - 1)) - 1
        } else {
            (1i64 << self.bits) - 1
        }
    }

    /// Number of distinct representable levels (`2^bits`).
    pub fn levels(&self) -> u64 {
        1u64 << self.bits
    }

    /// Clamp a wide integer into this type's range.
    pub fn clamp(&self, v: i64) -> i64 {
        v.clamp(self.min_value(), self.max_value())
    }

    /// True if `v` is representable without clipping.
    pub fn contains(&self, v: i64) -> bool {
        v >= self.min_value() && v <= self.max_value()
    }
}

impl fmt::Display for ElemType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}int{}", if self.signed { "" } else { "u" }, self.bits)
    }
}

/// Shape + element type of a tensor on an edge.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorSpec {
    /// Dimensions, outermost first. CNN feature maps use `[C, H, W]`
    /// (batch dimension implicit = 1, as in the paper's single-inference
    /// latency analysis).
    pub dims: Vec<usize>,
    /// Element type.
    pub elem: ElemType,
}

impl TensorSpec {
    /// A tensor of the given dimensions and element type.
    pub fn new(dims: Vec<usize>, elem: ElemType) -> Self {
        Self { dims, elem }
    }

    /// `[C, H, W]` feature map helper.
    pub fn chw(c: usize, h: usize, w: usize, elem: ElemType) -> Self {
        Self::new(vec![c, h, w], elem)
    }

    /// Total number of elements.
    pub fn num_elems(&self) -> usize {
        self.dims.iter().product()
    }

    /// Exact size in *bits* (no packing/padding assumptions).
    pub fn bits(&self) -> u64 {
        self.num_elems() as u64 * self.elem.bits as u64
    }

    /// Size in bytes with dense sub-byte packing, rounded up.
    pub fn bytes_packed(&self) -> u64 {
        self.bits().div_ceil(8)
    }

    /// Size in bytes if every element is stored byte-aligned (each element
    /// occupies `ceil(bits/8)` bytes) — how unpacked buffers are laid out
    /// in L1 for compute.
    pub fn bytes_unpacked(&self) -> u64 {
        self.num_elems() as u64 * (self.elem.bits as u64).div_ceil(8)
    }

    /// Channel count assuming `[C, H, W]` (or `[C]` / `[C, L]`) layout.
    pub fn channels(&self) -> usize {
        self.dims.first().copied().unwrap_or(1)
    }

    /// Spatial size `H*W` assuming `[C, H, W]`; 1 for vectors.
    pub fn spatial(&self) -> usize {
        if self.dims.len() >= 3 {
            self.dims[1..].iter().product()
        } else if self.dims.len() == 2 {
            self.dims[1]
        } else {
            1
        }
    }
}

impl fmt::Display for TensorSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "<{}>_{}", dims.join("x"), self.elem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_range() {
        let t = ElemType::int(8);
        assert_eq!(t.min_value(), -128);
        assert_eq!(t.max_value(), 127);
        assert_eq!(t.levels(), 256);
    }

    #[test]
    fn int4_range() {
        let t = ElemType::int(4);
        assert_eq!(t.min_value(), -8);
        assert_eq!(t.max_value(), 7);
    }

    #[test]
    fn uint2_range() {
        let t = ElemType::uint(2);
        assert_eq!(t.min_value(), 0);
        assert_eq!(t.max_value(), 3);
        assert_eq!(t.levels(), 4);
    }

    #[test]
    fn int32_range_no_overflow() {
        let t = ElemType::int(32);
        assert_eq!(t.min_value(), i32::MIN as i64);
        assert_eq!(t.max_value(), i32::MAX as i64);
    }

    #[test]
    fn clamp_clips_both_ends() {
        let t = ElemType::int(8);
        assert_eq!(t.clamp(1000), 127);
        assert_eq!(t.clamp(-1000), -128);
        assert_eq!(t.clamp(5), 5);
    }

    #[test]
    fn tensor_sizes_packed_vs_unpacked() {
        // 3 channels of 4x4 int4: 48 elements * 4 bits = 192 bits = 24 B packed,
        // 48 B byte-aligned.
        let t = TensorSpec::chw(3, 4, 4, ElemType::int(4));
        assert_eq!(t.num_elems(), 48);
        assert_eq!(t.bits(), 192);
        assert_eq!(t.bytes_packed(), 24);
        assert_eq!(t.bytes_unpacked(), 48);
    }

    #[test]
    fn tensor_odd_bits_round_up() {
        let t = TensorSpec::new(vec![3], ElemType::int(3));
        assert_eq!(t.bits(), 9);
        assert_eq!(t.bytes_packed(), 2);
    }

    #[test]
    fn spatial_and_channels() {
        let t = TensorSpec::chw(16, 8, 8, ElemType::int(8));
        assert_eq!(t.channels(), 16);
        assert_eq!(t.spatial(), 64);
        let v = TensorSpec::new(vec![10], ElemType::int(32));
        assert_eq!(v.channels(), 10);
        assert_eq!(v.spatial(), 1);
    }

    #[test]
    fn display_forms() {
        assert_eq!(ElemType::int(8).to_string(), "int8");
        assert_eq!(ElemType::uint(4).to_string(), "uint4");
        let t = TensorSpec::chw(3, 32, 32, ElemType::int(8));
        assert_eq!(t.to_string(), "<3x32x32>_int8");
    }
}
