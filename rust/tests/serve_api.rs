//! Integration tests for `aladin serve`: spawn the server in-process on an
//! ephemeral port and drive it over raw `TcpStream`s — golden round-trips
//! per endpoint, malformed/oversized requests answered with 4xx (never a
//! panic or a hang), the shared cache visible across clients, streamed
//! evolutionary fronts bit-identical to the direct search, and warm starts
//! across a restart through the on-disk cache tier (including corrupted
//! record files being skipped and recomputed, not trusted).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use aladin::dse::{evolve_with, EvalEngine, EvoConfig, SearchSpace};
use aladin::models;
use aladin::models::BlockImpl;
use aladin::platform::presets;
use aladin::serve::{spawn, ServeConfig};
use aladin::util::json::Value;
use aladin::util::tempdir::TempDir;
use aladin::util::ToJson;

fn ephemeral() -> ServeConfig {
    let mut config = ServeConfig::new("127.0.0.1:0");
    config.threads = Some(2);
    config
}

/// Hand-written HTTP client: one request over a raw `TcpStream`, response
/// aggregated until EOF (every server response is `Connection: close`).
fn raw_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len(),
    )
    .unwrap();
    stream.flush().unwrap();
    read_response(stream)
}

/// Read `(status, body)` from an open response stream.
fn read_response(stream: TcpStream) -> (u16, String) {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("numeric status");
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header).unwrap() == 0 || header.trim_end().is_empty() {
            break;
        }
    }
    let mut body = String::new();
    reader.read_to_string(&mut body).unwrap();
    (status, body)
}

fn parse(body: &str) -> Value {
    Value::parse(body).unwrap_or_else(|e| panic!("unparsable response `{body}`: {e}"))
}

/// A fast evolutionary job: case2 at width 0.25, 2 generations, tiny
/// budget — seconds, not minutes, yet it exercises every cached stage.
fn tiny_evo_body() -> &'static str {
    r#"{"model":"case2","width_mult":0.25,"bits":[4,8],"impls":["im2col"],
        "cores":[2,4],"l2_kb":[256],"population":4,"generations":2,
        "max_evals":12,"threads":2}"#
}

/// The same tiny product space for the deterministic joint explorer.
fn tiny_joint_body() -> &'static str {
    r#"{"model":"case2","width_mult":0.25,"bits":[4,8],"impls":["im2col"],
        "cores":[2,8],"l2_kb":[256],"threads":2}"#
}

/// Split an NDJSON stream body into parsed lines.
fn ndjson_lines(body: &str) -> Vec<Value> {
    body.lines()
        .filter(|l| !l.trim().is_empty())
        .map(parse)
        .collect()
}

#[test]
fn health_and_stats_round_trip() {
    let mut handle = spawn(ephemeral()).unwrap();
    let (status, body) = raw_request(handle.addr(), "GET", "/health", "");
    assert_eq!(status, 200);
    let v = parse(&body);
    assert_eq!(v.bool_field("ok"), Some(true));
    assert!(!v.str_field("version").unwrap().is_empty());

    let (status, body) = raw_request(handle.addr(), "GET", "/stats", "");
    assert_eq!(status, 200);
    let v = parse(&body);
    assert_eq!(v.usize_field("jobs_active"), Some(0));
    assert_eq!(v.get("disk_tier").and_then(Value::as_bool), Some(false));
    let stats = v.get("stats").expect("stats object");
    assert_eq!(stats.usize_field("sim_computed"), Some(0), "fresh server, cold cache");
    handle.shutdown();
}

#[test]
fn unknown_routes_and_wrong_methods_get_4xx() {
    let mut handle = spawn(ephemeral()).unwrap();
    let (status, body) = raw_request(handle.addr(), "GET", "/no/such/route", "");
    assert_eq!(status, 404);
    assert!(parse(&body).str_field("error").is_some());

    // known paths, wrong method
    for (method, path) in
        [("GET", "/v1/analyze"), ("POST", "/health"), ("DELETE", "/v1/dse/evo")]
    {
        let (status, _) = raw_request(handle.addr(), method, path, "");
        assert_eq!(status, 405, "{method} {path}");
    }
    handle.shutdown();
}

#[test]
fn malformed_requests_get_400_never_a_panic_or_hang() {
    let mut handle = spawn(ephemeral()).unwrap();

    // truncated JSON body
    let (status, body) = raw_request(handle.addr(), "POST", "/v1/analyze", r#"{"model":"#);
    assert_eq!(status, 400);
    assert!(parse(&body).str_field("error").is_some());

    // well-formed JSON, non-built-in model name (the hardening invariant:
    // file paths in request bodies must be rejected, not opened)
    let (status, body) = raw_request(
        handle.addr(),
        "POST",
        "/v1/analyze",
        r#"{"model":"/etc/passwd"}"#,
    );
    assert_eq!(status, 400);
    assert!(parse(&body).str_field("error").unwrap().contains("unknown model"));

    // mistyped field
    let (status, _) = raw_request(
        handle.addr(),
        "POST",
        "/v1/dse/evo",
        r#"{"population":"many"}"#,
    );
    assert_eq!(status, 400);

    // garbage request line
    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(b"THIS IS NOT HTTP\r\n\r\n").unwrap();
    let (status, _) = read_response(stream);
    assert_eq!(status, 400);

    // unparsable content-length
    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream
        .write_all(b"POST /v1/eval HTTP/1.1\r\nContent-Length: lots\r\n\r\n")
        .unwrap();
    let (status, _) = read_response(stream);
    assert_eq!(status, 400);

    // the server survived all of it
    let (status, _) = raw_request(handle.addr(), "GET", "/health", "");
    assert_eq!(status, 200);
    handle.shutdown();
}

#[test]
fn oversized_bodies_get_413_without_being_read() {
    let mut config = ephemeral();
    config.max_body_bytes = 256;
    let mut handle = spawn(config).unwrap();
    let big = format!(r#"{{"pad":"{}"}}"#, "x".repeat(4096));
    let (status, body) = raw_request(handle.addr(), "POST", "/v1/analyze", &big);
    assert_eq!(status, 413);
    assert!(parse(&body).str_field("error").is_some());
    let (status, _) = raw_request(handle.addr(), "GET", "/health", "");
    assert_eq!(status, 200);
    handle.shutdown();
}

#[test]
fn analyze_golden_round_trip_matches_direct_engine() {
    let mut handle = spawn(ephemeral()).unwrap();
    let (status, body) = raw_request(
        handle.addr(),
        "POST",
        "/v1/analyze",
        r#"{"model":"case2","width_mult":0.25,"cores":4,"l2_kb":320}"#,
    );
    assert_eq!(status, 200);
    let v = parse(&body);
    let record = v.get("record").expect("record object");
    assert_eq!(record.usize_field("cores"), Some(4));
    assert_eq!(record.u64_field("l2_kb"), Some(320));

    // golden reference: the same point through a direct in-process engine
    let mut case = models::case2();
    case.width_mult = 0.25;
    let engine =
        EvalEngine::for_mobilenet(case, presets::gap8()).with_threads(2);
    let direct = engine.evaluate(&aladin::dse::DesignVector::of_hw(4, 320)).unwrap();
    assert_eq!(record.u64_field("total_cycles"), Some(direct.total_cycles));
    assert_eq!(
        record.to_string_compact(),
        direct.to_json().to_string_compact(),
        "server record must be byte-identical to the direct evaluation"
    );

    // per-job stats delta: a cold job computes, it does not hit
    let stats = v.get("stats").expect("stats object");
    assert_eq!(stats.usize_field("sim_computed"), Some(1));
    assert_eq!(stats.usize_field("sim_hits"), Some(0));
    handle.shutdown();
}

#[test]
fn eval_endpoint_reports_measured_accuracy() {
    let mut handle = spawn(ephemeral()).unwrap();
    let (status, body) = raw_request(
        handle.addr(),
        "POST",
        "/v1/eval",
        r#"{"model":"case2","width_mult":0.25,"cores":2,"l2_kb":256,"vectors":2}"#,
    );
    assert_eq!(status, 200);
    let v = parse(&body);
    let record = v.get("record").expect("record object");
    let acc = record.f64_field("accuracy").expect("accuracy populated");
    assert!((0.0..=1.0).contains(&acc));
    let stats = v.get("stats").expect("stats object");
    assert_eq!(stats.usize_field("acc_computed"), Some(1));
    handle.shutdown();
}

#[test]
fn second_identical_joint_job_runs_on_the_first_ones_cache() {
    let mut handle = spawn(ephemeral()).unwrap();
    let run = || {
        let (status, body) =
            raw_request(handle.addr(), "POST", "/v1/dse/joint", tiny_joint_body());
        assert_eq!(status, 200);
        parse(&body)
    };
    let first = run();
    let second = run();

    // two clients, one shared cache: the second identical job reports
    // layer- and stage-tier hits from the first one's work
    let cold = first.get("stats").expect("stats");
    let warm = second.get("stats").expect("stats");
    assert!(cold.usize_field("sim_computed").unwrap() > 0);
    assert_eq!(warm.usize_field("sim_computed"), Some(0), "warm job must not re-simulate");
    assert_eq!(warm.usize_field("impl_computed"), Some(0), "warm job must not re-decorate");
    assert!(warm.usize_field("sim_hits").unwrap() > 0);
    assert!(warm.usize_field("impl_hits").unwrap() > 0);
    assert!(warm.usize_field("layer_hits").unwrap() > 0);

    // and the fronts are byte-identical
    assert_eq!(first.usize_field("evaluated"), second.usize_field("evaluated"));
    assert_eq!(
        first.get("front_records").unwrap().to_string_compact(),
        second.get("front_records").unwrap().to_string_compact(),
    );
    handle.shutdown();
}

#[test]
fn streamed_evo_job_is_bit_identical_to_the_direct_search() {
    let mut handle = spawn(ephemeral()).unwrap();
    let (status, body) = raw_request(handle.addr(), "POST", "/v1/dse/evo", tiny_evo_body());
    assert_eq!(status, 200);
    let lines = ndjson_lines(&body);
    assert!(lines.len() >= 2, "expected generation lines + final line, got {}", lines.len());
    let (gens, fin) = lines.split_at(lines.len() - 1);
    let fin = &fin[0];
    assert_eq!(fin.bool_field("done"), Some(true));
    assert_eq!(fin.usize_field("generations"), Some(gens.len()));

    // the existing seeded-determinism property, re-run through the server
    // path: an identically-configured direct search must produce the same
    // generation stream and the same front, byte for byte
    let mut case = models::case2();
    case.width_mult = 0.25;
    let n_blocks = case.blocks.len();
    let engine = EvalEngine::for_mobilenet(case, presets::gap8()).with_threads(2);
    let space = SearchSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        n_blocks,
        cores: vec![2, 4],
        l2_kb: vec![256],
        backends: vec![],
    };
    let cfg = EvoConfig {
        population: 4,
        generations: 2,
        max_evals: 12,
        ..EvoConfig::default()
    };
    let mut direct_gens: Vec<String> = Vec::new();
    let result = evolve_with(&engine, &space, &cfg, |s| {
        direct_gens.push(s.to_json().to_string_compact());
    })
    .unwrap();

    let streamed_gens: Vec<String> =
        gens.iter().map(Value::to_string_compact).collect();
    assert_eq!(streamed_gens, direct_gens, "per-generation stream diverged");
    let direct_front: Vec<Value> =
        result.front.iter().map(|&i| result.records[i].to_json()).collect();
    assert_eq!(
        fin.get("front_records").unwrap().to_string_compact(),
        Value::Arr(direct_front).to_string_compact(),
        "streamed front diverged from the direct search"
    );
    assert_eq!(fin.usize_field("evaluations"), Some(result.evaluations));
    handle.shutdown();
}

/// Run the tiny evo job against `addr`, returning the final NDJSON line.
fn run_tiny_evo(addr: SocketAddr) -> Value {
    let (status, body) = raw_request(addr, "POST", "/v1/dse/evo", tiny_evo_body());
    assert_eq!(status, 200);
    let lines = ndjson_lines(&body);
    let fin = lines.last().expect("final line").clone();
    assert_eq!(fin.bool_field("done"), Some(true));
    fin
}

#[test]
fn warm_start_across_restart_serves_from_the_disk_tier() {
    let dir = TempDir::new().unwrap();

    // first server lifetime: cold run, then drop the server via its own
    // /shutdown endpoint (drains in-flight work, flushes the write-behind)
    let mut config = ephemeral();
    config.cache_dir = Some(dir.path().to_path_buf());
    let handle = spawn(config).unwrap();
    let cold = run_tiny_evo(handle.addr());
    let cold_stats = cold.get("stats").expect("stats");
    assert_eq!(cold_stats.usize_field("disk_hits"), Some(0), "nothing on disk yet");
    assert!(cold_stats.usize_field("disk_stores").unwrap() > 0, "write-behind engaged");
    let (status, _) = raw_request(handle.addr(), "POST", "/shutdown", "{}");
    assert_eq!(status, 200);
    handle.join();
    let records: Vec<_> = std::fs::read_dir(dir.path())
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "rec"))
        .collect();
    assert!(!records.is_empty(), "shutdown must leave flushed record files");

    // second server lifetime, same directory: the memory tier is cold but
    // the rerun is served from disk and the front is byte-identical
    let mut config = ephemeral();
    config.cache_dir = Some(dir.path().to_path_buf());
    let mut handle = spawn(config).unwrap();
    let warm = run_tiny_evo(handle.addr());
    let warm_stats = warm.get("stats").expect("stats");
    assert!(warm_stats.usize_field("disk_hits").unwrap() > 0, "disk tier must serve the rerun");
    assert_eq!(warm_stats.usize_field("sim_computed"), Some(0), "sim stage replayed from disk");
    assert_eq!(
        cold.get("front_records").unwrap().to_string_compact(),
        warm.get("front_records").unwrap().to_string_compact(),
        "warm-start front must be byte-identical to the first run's"
    );
    handle.shutdown();
}

#[test]
fn corrupt_disk_records_are_skipped_and_recomputed_not_trusted() {
    let dir = TempDir::new().unwrap();
    let mut config = ephemeral();
    config.cache_dir = Some(dir.path().to_path_buf());
    let mut handle = spawn(config).unwrap();
    let cold = run_tiny_evo(handle.addr());
    handle.shutdown();

    // vandalize the persisted tier: truncate one record, flip a payload
    // byte (breaking the checksum) in another
    let mut records: Vec<std::path::PathBuf> = std::fs::read_dir(dir.path())
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "rec"))
        .collect();
    records.sort();
    assert!(records.len() >= 2, "need at least two records to corrupt");
    let truncated = &records[0];
    let bytes = std::fs::read(truncated).unwrap();
    std::fs::write(truncated, &bytes[..bytes.len() / 2]).unwrap();
    let flipped = &records[1];
    let mut bytes = std::fs::read(flipped).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    std::fs::write(flipped, &bytes).unwrap();

    // restart on the vandalized directory: the corrupt records are counted,
    // skipped, and recomputed — the front stays byte-identical
    let mut config = ephemeral();
    config.cache_dir = Some(dir.path().to_path_buf());
    let mut handle = spawn(config).unwrap();
    let warm = run_tiny_evo(handle.addr());
    let warm_stats = warm.get("stats").expect("stats");
    assert!(
        warm_stats.usize_field("disk_corrupt").unwrap() >= 2,
        "both vandalized records must be detected"
    );
    assert!(warm_stats.usize_field("disk_hits").unwrap() > 0, "intact records still serve");
    assert_eq!(
        cold.get("front_records").unwrap().to_string_compact(),
        warm.get("front_records").unwrap().to_string_compact(),
        "corruption must cause recomputation, never a divergent front"
    );
    handle.shutdown();
}
