//! Integration tests for the DSE evaluation cache: cached replays are
//! bit-identical to cold evaluations, the cache provably recomputes fewer
//! pipeline stages than a cache-less evaluator, and the joint explorer's
//! Pareto front is deterministic across thread counts.

use aladin::dse::{
    explore_joint, explore_joint_measured, DesignVector, EvalEngine, GridSearch, HwAxis,
    JointResult, JointSpace, QuantAxis,
};
use aladin::impl_aware::decorate;
use aladin::models;
use aladin::models::{BlockImpl, MobileNetConfig};
use aladin::platform::presets;
use aladin::sim::SimResult;
use std::sync::Arc;

fn small(mut case: MobileNetConfig) -> MobileNetConfig {
    case.width_mult = 0.25; // keep integration runs fast
    case
}

fn assert_sims_bit_identical(a: &SimResult, b: &SimResult) {
    assert_eq!(a.platform, b.platform);
    assert_eq!(a.cores, b.cores);
    assert_eq!(a.l2_kb, b.l2_kb);
    assert_eq!(a.layers.len(), b.layers.len());
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.cycles, y.cycles);
        assert_eq!(x.compute_cycles, y.compute_cycles);
        assert_eq!(x.dma_l1_cycles, y.dma_l1_cycles);
        assert_eq!(x.dma_l3_cycles, y.dma_l3_cycles);
        assert_eq!(x.exposed_dma_l1_cycles, y.exposed_dma_l1_cycles);
        assert_eq!(x.exposed_dma_l3_cycles, y.exposed_dma_l3_cycles);
        assert_eq!(x.hidden_dma_l3_cycles, y.hidden_dma_l3_cycles);
        assert_eq!(x.stall_cycles, y.stall_cycles);
        assert_eq!(x.l1_used_bytes, y.l1_used_bytes);
        assert_eq!(x.l2_used_bytes, y.l2_used_bytes);
        assert_eq!(x.n_tiles, y.n_tiles);
        assert_eq!(x.double_buffered, y.double_buffered);
        // the resource-timeline accounting identity holds for every
        // cached-or-cold layer result
        assert_eq!(
            x.compute_cycles + x.exposed_dma_l1_cycles + x.exposed_dma_l3_cycles,
            x.cycles,
            "{}",
            x.name
        );
    }
}

#[test]
fn cached_and_cold_evaluations_bit_identical() {
    let vector = DesignVector {
        quant: Some(QuantAxis::uniform(4, BlockImpl::Im2col, 10)),
        hw: Some(HwAxis { cores: 4, l2_kb: 320 }),
    };

    // cold: a fresh engine, first evaluation
    let cold_engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let cold = cold_engine.evaluate(&vector).unwrap();

    // warm: a second fresh engine, evaluated twice — the second run is
    // served entirely from the cache
    let warm_engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    warm_engine.evaluate(&vector).unwrap();
    let cached = warm_engine.evaluate(&vector).unwrap();
    let stats = warm_engine.stats();
    assert_eq!(stats.impl_computed, 1);
    assert_eq!(stats.sim_computed, 1);
    assert_eq!(stats.impl_hits, 1);
    assert_eq!(stats.sim_hits, 1);

    assert_eq!(cold.total_cycles, cached.total_cycles);
    assert_eq!(cold.latency_s.to_bits(), cached.latency_s.to_bits());
    assert_eq!(cold.sensitivity.to_bits(), cached.sensitivity.to_bits());
    assert_eq!(cold.param_kb.to_bits(), cached.param_kb.to_bits());
    assert_eq!(cold.mem_kb.to_bits(), cached.mem_kb.to_bits());
    assert_eq!(cold.tilings, cached.tilings);
    assert_sims_bit_identical(&cold.sim, &cached.sim);
}

#[test]
fn fig7_grid_recomputes_fewer_stages_than_point_count_times_stage_count() {
    let (g, cfg) = small(models::case2()).build();
    let decorated = decorate(g, &cfg).unwrap();
    let engine = EvalEngine::for_decorated(decorated, presets::gap8());
    let points = GridSearch::fig7(presets::gap8()).run_on(&engine).unwrap();
    assert_eq!(points.len(), 9);

    // the acceptance criterion: strictly fewer pipeline-stage
    // recomputations than point-count x stage-count
    const STAGES: usize = 2; // decorate+fuse, schedule+simulate
    let stats = engine.stats();
    assert!(
        stats.recomputations() < points.len() * STAGES,
        "expected < {} stage computations, got {}",
        points.len() * STAGES,
        stats.recomputations()
    );
    // exact accounting: one shared stage-1, one stage-2 per grid point
    assert_eq!(stats.impl_computed, 1);
    assert_eq!(stats.sim_computed, 9);
}

#[test]
fn joint_product_space_shares_stage1_across_hardware_points() {
    let space = JointSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        tail_k: 0,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
    };
    let result = explore_joint(small(models::case2()), presets::gap8(), &space, None).unwrap();
    assert_eq!(result.records.len(), 8); // 2 quant x 4 hw
    // each quant config decorated exactly once, each candidate simulated once
    assert_eq!(result.stats.impl_computed, 2);
    assert_eq!(result.stats.sim_computed, 8);
    assert_eq!(result.stats.impl_hits, 6);
    assert!(result.stats.recomputations() < result.records.len() * 2);
}

#[test]
fn joint_pareto_front_deterministic_across_thread_counts() {
    let space = JointSpace {
        bits: vec![2, 4, 8],
        impls: vec![BlockImpl::Im2col],
        tail_k: 0,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
    };
    let run = |threads: usize| -> JointResult {
        explore_joint(small(models::case1()), presets::gap8(), &space, Some(threads)).unwrap()
    };
    let r1 = run(1);
    let r4 = run(4);
    let r7 = run(7);

    let fingerprint = |r: &JointResult| -> Vec<(u64, usize, u64, u64, u64)> {
        r.records
            .iter()
            .map(|x| {
                (
                    x.total_cycles,
                    x.cores,
                    x.l2_kb,
                    x.sensitivity.to_bits(),
                    x.mem_kb.to_bits(),
                )
            })
            .collect()
    };
    assert_eq!(fingerprint(&r1), fingerprint(&r4));
    assert_eq!(fingerprint(&r1), fingerprint(&r7));
    assert_eq!(r1.front, r4.front);
    assert_eq!(r1.front, r7.front);
    assert!(!r1.front.is_empty());
}

#[test]
fn measured_accuracy_stage_cache_hits_across_fig7_hw_grid() {
    // the acceptance criterion for `--measured-accuracy`: the accuracy
    // stage is keyed by the quant-axis content hash only, so the whole
    // Fig. 7 hardware grid reuses ONE interpreter evaluation — and every
    // point reports bit-identical accuracy (hardware-axis invariance).
    let vectors = Arc::new(models::cifar_vectors(3));
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
        .with_measured_accuracy(vectors);
    let grid: Vec<DesignVector> = [2usize, 4, 8]
        .iter()
        .flat_map(|&c| [256u64, 320, 512].iter().map(move |&l2| DesignVector::of_hw(c, l2)))
        .collect();
    let records = engine.evaluate_all(&grid).unwrap();
    assert_eq!(records.len(), 9);

    let acc = records[0].accuracy.expect("measured accuracy populated");
    let fp = records[0].accuracy_fingerprint.expect("fingerprint populated");
    assert!((0.0..=1.0).contains(&acc));
    for r in &records {
        assert_eq!(r.accuracy.unwrap().to_bits(), acc.to_bits());
        assert_eq!(r.accuracy_fingerprint.unwrap(), fp);
    }
    let s = engine.stats();
    assert_eq!(s.acc_computed, 1, "one interpreter eval for 9 hardware points");
    assert_eq!(s.acc_hits, 8);
    // the latency stages keep their own accounting
    assert_eq!(s.impl_computed, 1);
    assert_eq!(s.sim_computed, 9);
}

#[test]
fn joint_measured_accuracy_is_deterministic_across_thread_counts() {
    let space = JointSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        tail_k: 0,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
    };
    let run = |threads: usize| {
        explore_joint_measured(
            small(models::case2()),
            presets::gap8(),
            &space,
            Some(threads),
            Some(Arc::new(models::cifar_vectors(2))),
        )
        .unwrap()
    };
    let r1 = run(1);
    let r4 = run(4);
    assert!(r1.measured && r4.measured);
    let acc = |r: &JointResult| -> Vec<u64> {
        r.records
            .iter()
            .map(|x| x.accuracy.unwrap().to_bits())
            .collect()
    };
    assert_eq!(acc(&r1), acc(&r4));
    assert_eq!(r1.front, r4.front);
    // per quant configuration: exactly one interpreter run
    assert_eq!(r1.stats.acc_computed, 2);
    assert_eq!(r4.stats.acc_computed, 2);
}

#[test]
fn grid_search_results_unchanged_by_engine_port() {
    // the ported GridSearch must agree with a hand-driven Pipeline run
    let (g, cfg) = small(models::case2()).build();
    let points = GridSearch::fig7(presets::gap8())
        .run_canonical(g.clone(), &cfg)
        .unwrap();
    for p in &points {
        let direct = aladin::coordinator::Pipeline::new(
            presets::gap8_with(p.cores, p.l2_kb),
            cfg.clone(),
        )
        .analyze(g.clone())
        .unwrap();
        assert_eq!(p.total_cycles, direct.latency.total_cycles, "c{} l2 {}", p.cores, p.l2_kb);
        assert_eq!(p.sim.layers.len(), direct.sim.layers.len());
    }
}
