//! Integration tests for the DSE evaluation cache: cached replays are
//! bit-identical to cold evaluations, the cache provably recomputes fewer
//! pipeline stages than a cache-less evaluator, and the joint explorer's
//! Pareto front is deterministic across thread counts.

use aladin::dse::{
    explore_joint, explore_joint_measured, DesignVector, EvalEngine, GridSearch, HwAxis,
    JointResult, JointSpace, QuantAxis,
};
use aladin::impl_aware::decorate;
use aladin::models;
use aladin::models::{BlockImpl, MobileNetConfig};
use aladin::platform::presets;
use aladin::sim::SimResult;
use std::sync::Arc;

fn assert_records_bit_identical(a: &aladin::dse::EvalRecord, b: &aladin::dse::EvalRecord) {
    assert_eq!(a.cores, b.cores);
    assert_eq!(a.l2_kb, b.l2_kb);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
    assert_eq!(a.sensitivity.to_bits(), b.sensitivity.to_bits());
    assert_eq!(a.param_kb.to_bits(), b.param_kb.to_bits());
    assert_eq!(a.mem_kb.to_bits(), b.mem_kb.to_bits());
    assert_eq!(a.peak_l1_kb.to_bits(), b.peak_l1_kb.to_bits());
    assert_eq!(a.peak_l2_kb.to_bits(), b.peak_l2_kb.to_bits());
    assert_eq!(a.l3_traffic_kb.to_bits(), b.l3_traffic_kb.to_bits());
    assert_eq!(a.energy_nj.to_bits(), b.energy_nj.to_bits());
    assert_eq!(a.tilings, b.tilings);
    assert_sims_bit_identical(&a.sim, &b.sim);
}

fn small(mut case: MobileNetConfig) -> MobileNetConfig {
    case.width_mult = 0.25; // keep integration runs fast
    case
}

fn assert_sims_bit_identical(a: &SimResult, b: &SimResult) {
    assert_eq!(a.platform, b.platform);
    assert_eq!(a.backend, b.backend);
    assert_eq!(a.cores, b.cores);
    assert_eq!(a.l2_kb, b.l2_kb);
    assert_eq!(a.layers.len(), b.layers.len());
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.cycles, y.cycles);
        assert_eq!(x.compute_cycles, y.compute_cycles);
        assert_eq!(x.dma_l1_cycles, y.dma_l1_cycles);
        assert_eq!(x.dma_l3_cycles, y.dma_l3_cycles);
        assert_eq!(x.exposed_dma_l1_cycles, y.exposed_dma_l1_cycles);
        assert_eq!(x.exposed_dma_l3_cycles, y.exposed_dma_l3_cycles);
        assert_eq!(x.hidden_dma_l3_cycles, y.hidden_dma_l3_cycles);
        assert_eq!(x.stall_cycles, y.stall_cycles);
        assert_eq!(x.l1_used_bytes, y.l1_used_bytes);
        assert_eq!(x.l2_used_bytes, y.l2_used_bytes);
        assert_eq!(x.n_tiles, y.n_tiles);
        assert_eq!(x.double_buffered, y.double_buffered);
        // the resource-timeline accounting identity holds for every
        // cached-or-cold layer result
        assert_eq!(
            x.compute_cycles + x.exposed_dma_l1_cycles + x.exposed_dma_l3_cycles,
            x.cycles,
            "{}",
            x.name
        );
    }
}

#[test]
fn cached_and_cold_evaluations_bit_identical() {
    let vector = DesignVector {
        quant: Some(QuantAxis::uniform(4, BlockImpl::Im2col, 10)),
        hw: Some(HwAxis { cores: 4, l2_kb: 320, backend: None }),
    };

    // cold: a fresh engine, first evaluation
    let cold_engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let cold = cold_engine.evaluate(&vector).unwrap();

    // warm: a second fresh engine, evaluated twice — the second run is
    // served entirely from the cache
    let warm_engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    warm_engine.evaluate(&vector).unwrap();
    let cached = warm_engine.evaluate(&vector).unwrap();
    let stats = warm_engine.stats();
    assert_eq!(stats.impl_computed, 1);
    assert_eq!(stats.sim_computed, 1);
    assert_eq!(stats.impl_hits, 1);
    assert_eq!(stats.sim_hits, 1);

    assert_eq!(cold.total_cycles, cached.total_cycles);
    assert_eq!(cold.latency_s.to_bits(), cached.latency_s.to_bits());
    assert_eq!(cold.sensitivity.to_bits(), cached.sensitivity.to_bits());
    assert_eq!(cold.param_kb.to_bits(), cached.param_kb.to_bits());
    assert_eq!(cold.mem_kb.to_bits(), cached.mem_kb.to_bits());
    assert_eq!(cold.tilings, cached.tilings);
    assert_sims_bit_identical(&cold.sim, &cached.sim);
}

#[test]
fn fig7_grid_recomputes_fewer_stages_than_point_count_times_stage_count() {
    let (g, cfg) = small(models::case2()).build();
    let decorated = decorate(g, &cfg).unwrap();
    let engine = EvalEngine::for_decorated(decorated, presets::gap8());
    let points = GridSearch::fig7(presets::gap8()).run_on(&engine).unwrap();
    assert_eq!(points.len(), 9);

    // the acceptance criterion: strictly fewer pipeline-stage
    // recomputations than point-count x stage-count
    const STAGES: usize = 2; // decorate+fuse, schedule+simulate
    let stats = engine.stats();
    assert!(
        stats.recomputations() < points.len() * STAGES,
        "expected < {} stage computations, got {}",
        points.len() * STAGES,
        stats.recomputations()
    );
    // exact accounting: one shared stage-1, one stage-2 per grid point
    assert_eq!(stats.impl_computed, 1);
    assert_eq!(stats.sim_computed, 9);
}

#[test]
fn joint_product_space_shares_stage1_across_hardware_points() {
    let space = JointSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        tail_k: 0,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
        backends: vec![],
    };
    let result = explore_joint(small(models::case2()), presets::gap8(), &space, None).unwrap();
    assert_eq!(result.records.len(), 8); // 2 quant x 4 hw
    // each quant config decorated exactly once, each candidate simulated once
    assert_eq!(result.stats.impl_computed, 2);
    assert_eq!(result.stats.sim_computed, 8);
    assert_eq!(result.stats.impl_hits, 6);
    assert!(result.stats.recomputations() < result.records.len() * 2);
}

#[test]
fn joint_pareto_front_deterministic_across_thread_counts() {
    let space = JointSpace {
        bits: vec![2, 4, 8],
        impls: vec![BlockImpl::Im2col],
        tail_k: 0,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
        backends: vec![],
    };
    let run = |threads: usize| -> JointResult {
        explore_joint(small(models::case1()), presets::gap8(), &space, Some(threads)).unwrap()
    };
    let r1 = run(1);
    let r4 = run(4);
    let r7 = run(7);

    let fingerprint = |r: &JointResult| -> Vec<(u64, usize, u64, u64, u64)> {
        r.records
            .iter()
            .map(|x| {
                (
                    x.total_cycles,
                    x.cores,
                    x.l2_kb,
                    x.sensitivity.to_bits(),
                    x.mem_kb.to_bits(),
                )
            })
            .collect()
    };
    assert_eq!(fingerprint(&r1), fingerprint(&r4));
    assert_eq!(fingerprint(&r1), fingerprint(&r7));
    assert_eq!(r1.front, r4.front);
    assert_eq!(r1.front, r7.front);
    assert!(!r1.front.is_empty());
}

#[test]
fn measured_accuracy_stage_cache_hits_across_fig7_hw_grid() {
    // the acceptance criterion for `--measured-accuracy`: the accuracy
    // stage is keyed by the quant-axis content hash only, so the whole
    // Fig. 7 hardware grid reuses ONE interpreter evaluation — and every
    // point reports bit-identical accuracy (hardware-axis invariance).
    let vectors = Arc::new(models::cifar_vectors(3));
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
        .with_measured_accuracy(vectors);
    let grid: Vec<DesignVector> = [2usize, 4, 8]
        .iter()
        .flat_map(|&c| [256u64, 320, 512].iter().map(move |&l2| DesignVector::of_hw(c, l2)))
        .collect();
    let records = engine.evaluate_all(&grid).unwrap();
    assert_eq!(records.len(), 9);

    let acc = records[0].accuracy.expect("measured accuracy populated");
    let fp = records[0].accuracy_fingerprint.expect("fingerprint populated");
    assert!((0.0..=1.0).contains(&acc));
    for r in &records {
        assert_eq!(r.accuracy.unwrap().to_bits(), acc.to_bits());
        assert_eq!(r.accuracy_fingerprint.unwrap(), fp);
    }
    let s = engine.stats();
    assert_eq!(s.acc_computed, 1, "one interpreter eval for 9 hardware points");
    assert_eq!(s.acc_hits, 8);
    // the latency stages keep their own accounting
    assert_eq!(s.impl_computed, 1);
    assert_eq!(s.sim_computed, 9);
}

#[test]
fn joint_measured_accuracy_is_deterministic_across_thread_counts() {
    let space = JointSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        tail_k: 0,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
        backends: vec![],
    };
    let run = |threads: usize| {
        explore_joint_measured(
            small(models::case2()),
            presets::gap8(),
            &space,
            Some(threads),
            Some(Arc::new(models::cifar_vectors(2))),
        )
        .unwrap()
    };
    let r1 = run(1);
    let r4 = run(4);
    assert!(r1.measured && r4.measured);
    let acc = |r: &JointResult| -> Vec<u64> {
        r.records
            .iter()
            .map(|x| x.accuracy.unwrap().to_bits())
            .collect()
    };
    assert_eq!(acc(&r1), acc(&r4));
    assert_eq!(r1.front, r4.front);
    // per quant configuration: exactly one interpreter run
    assert_eq!(r1.stats.acc_computed, 2);
    assert_eq!(r4.stats.acc_computed, 2);
}

/// Fused layers of `small(case2)` under a quant axis — the ground truth
/// for "which layer-grained units did a mutation actually change".
fn fused_under(axis: &QuantAxis) -> Vec<aladin::platform_aware::FusedLayer> {
    let mut case = small(models::case2());
    axis.apply(&mut case);
    let (g, cfg) = case.build();
    aladin::coordinator::stage_impl(g, &cfg).unwrap().fused
}

fn changed_units(a: &QuantAxis, b: &QuantAxis) -> usize {
    let fa = fused_under(a);
    let fb = fused_under(b);
    assert_eq!(fa.len(), fb.len());
    fa.iter()
        .zip(&fb)
        .filter(|(x, y)| x.content_hash() != y.content_hash())
        .count()
}

#[test]
fn k_gene_mutation_recomputes_exactly_the_changed_layer_units() {
    // the acceptance criterion for the layer-grained tier: a k-gene
    // mutation recomputes exactly the k changed blocks' layer units (plus
    // the precision-coupled neighbor), never the whole network
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let hw = HwAxis { cores: 4, l2_kb: 320, backend: None };
    let base_q = QuantAxis::uniform(8, BlockImpl::Im2col, 10);
    let base = DesignVector {
        quant: Some(base_q.clone()),
        hw: Some(hw),
    };
    let rec = engine.evaluate(&base).unwrap();
    let total_layers = rec.sim.layers.len();
    let s0 = engine.stats();
    assert_eq!(s0.layer_computed, total_layers, "cold run computes every unit");

    // k = 1: one block's bits flip
    let mut q1 = base_q.clone();
    q1.bits[4] = 4;
    let v1 = DesignVector {
        quant: Some(q1.clone()),
        hw: Some(hw),
    };
    engine.evaluate_delta(&base, &v1).unwrap();
    let s1 = engine.stats();
    let expected1 = changed_units(&base_q, &q1);
    assert!(expected1 > 0, "a bit flip must change some layer unit");
    assert!(
        expected1 < total_layers / 2,
        "a 1-gene mutation may not invalidate most of the network \
         ({expected1} of {total_layers})"
    );
    assert_eq!(
        s1.layer_computed - s0.layer_computed,
        expected1,
        "1-gene mutation must recompute exactly the changed units"
    );

    // k = 2: two more blocks flip relative to q1 (block 8 takes a sub-byte
    // LUT, whose table fits L1 — an 8-bit LUT would be infeasible)
    let mut q2 = q1.clone();
    q2.bits[1] = 2;
    q2.bits[8] = 2;
    q2.impls[8] = BlockImpl::Lut;
    let v2 = DesignVector {
        quant: Some(q2.clone()),
        hw: Some(hw),
    };
    engine.evaluate_delta(&v1, &v2).unwrap();
    let s2 = engine.stats();
    let expected2 = changed_units(&q1, &q2);
    assert!(expected2 > 0 && expected2 < total_layers / 2);
    assert_eq!(
        s2.layer_computed - s1.layer_computed,
        expected2,
        "2-gene mutation must recompute exactly the changed units"
    );
    // the delta path actually engaged on both offspring
    assert_eq!(s2.impl_delta, 2);
    assert!(s2.nodes_reused > 0);
}

#[test]
fn evaluate_delta_chain_is_bit_identical_to_from_scratch() {
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let hw = HwAxis { cores: 8, l2_kb: 512, backend: None };
    let base_q = QuantAxis::uniform(8, BlockImpl::Im2col, 10);
    let mut prev = DesignVector {
        quant: Some(base_q.clone()),
        hw: Some(hw),
    };
    engine.evaluate(&prev).unwrap();
    // a short hand-built mutation chain: bits, impls, and hardware moves
    let steps: Vec<DesignVector> = {
        let mut q_a = base_q.clone();
        q_a.bits[2] = 4;
        let mut q_b = q_a.clone();
        q_b.bits[9] = 4;
        q_b.impls[9] = BlockImpl::Lut;
        let q_c = q_b.clone();
        vec![
            DesignVector { quant: Some(q_a), hw: Some(hw) },
            DesignVector { quant: Some(q_b), hw: Some(hw) },
            DesignVector {
                quant: Some(q_c),
                hw: Some(HwAxis { cores: 2, l2_kb: 256, backend: None }),
            },
        ]
    };
    for vector in steps {
        let delta = engine.evaluate_delta(&prev, &vector).unwrap();
        // reference: cold engine, full pipeline
        let scratch = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
            .evaluate(&vector)
            .unwrap();
        assert_records_bit_identical(&delta, &scratch);
        prev = vector;
    }
}

#[test]
fn engine_lower_bound_matches_schedule_level_bound() {
    // the engine's unit-spliced bound must be bit-identical to
    // sim::lower_bound_cycles over the built schedule
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let impl_model = {
        let (g, cfg) = small(models::case2()).build();
        aladin::coordinator::stage_impl(g, &cfg).unwrap()
    };
    for (cores, l2_kb) in [(2usize, 256u64), (4, 320), (8, 512)] {
        let v = DesignVector::of_hw(cores, l2_kb);
        let engine_bound = engine.latency_lower_bound(&v).unwrap();
        let platform = Arc::new(presets::gap8().reconfigure(cores, l2_kb * 1024));
        let schedule =
            aladin::platform_aware::build_schedule(&impl_model.fused, &platform).unwrap();
        assert_eq!(
            engine_bound,
            aladin::sim::lower_bound_cycles(&schedule),
            "c{cores}/l2 {l2_kb}"
        );
    }
}

#[test]
fn backend_swap_invalidates_exactly_the_platform_half_of_the_cache() {
    // satellite criterion for the Backend tentpole: the backend sits in
    // the platform content hash, so swapping it re-runs every layer unit
    // (platform-half keyed) but never the quant-axis stages — and swapping
    // back is served entirely from cache
    use aladin::sim::BackendKind;
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let base = DesignVector {
        quant: Some(QuantAxis::uniform(8, BlockImpl::Im2col, 10)),
        hw: Some(HwAxis { cores: 4, l2_kb: 320, backend: None }),
    };
    let r0 = engine.evaluate(&base).unwrap();
    assert_eq!(r0.sim.backend, "scratchpad");
    let total_layers = r0.sim.layers.len();
    let s0 = engine.stats();
    assert_eq!(s0.layer_computed, total_layers, "cold run computes every unit");
    assert_eq!(s0.impl_computed, 1);

    let swapped = DesignVector {
        quant: Some(QuantAxis::uniform(8, BlockImpl::Im2col, 10)),
        hw: Some(HwAxis {
            cores: 4,
            l2_kb: 320,
            backend: Some(BackendKind::SystolicArray),
        }),
    };
    let r1 = engine.evaluate(&swapped).unwrap();
    assert_eq!(r1.sim.backend, "systolic");
    let s1 = engine.stats();
    assert_eq!(s1.impl_computed, 1, "backend swap must not re-decorate");
    assert_eq!(s1.impl_hits, s0.impl_hits + 1, "quant-axis stage stays a hit");
    assert_eq!(s1.sim_computed, s0.sim_computed + 1);
    assert_eq!(
        s1.layer_computed,
        s0.layer_computed + total_layers,
        "a backend swap re-keys exactly the platform half of every unit"
    );

    // swap back: bit-identical to the first run, all units cached
    let r2 = engine.evaluate(&base).unwrap();
    let s2 = engine.stats();
    assert_eq!(r2.total_cycles, r0.total_cycles);
    assert_eq!(r2.energy_nj.to_bits(), r0.energy_nj.to_bits());
    assert_eq!(s2.layer_computed, s1.layer_computed, "swap back must hit every unit");
    assert_eq!(s2.sim_computed, s1.sim_computed);
    assert!(s2.sim_hits > s1.sim_hits);
}

#[test]
fn sharded_memo_stress_computes_each_key_exactly_once_across_threads() {
    // 8 threads hammer the same 64 keys; the sharded map must behave
    // exactly like the old single-lock memo: one compute per key, every
    // other access a hit, values bit-identical to a sequential reference
    use aladin::dse::ShardedMemo;
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    const KEYS: u64 = 64;
    const THREADS: usize = 8;
    let value_of = |k: u64| -> u64 { k.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (k >> 7) };
    // reference: the single-lock shape, built sequentially
    let mut reference: HashMap<u64, u64> = HashMap::new();
    for k in 0..KEYS {
        reference.insert(k, value_of(k));
    }

    let memo: ShardedMemo<u64> = ShardedMemo::new();
    let computed = AtomicUsize::new(0);
    let observed: Mutex<Vec<(u64, u64)>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for k in 0..KEYS {
                    let v = memo
                        .get_or_compute(k, || {
                            computed.fetch_add(1, Ordering::SeqCst);
                            Ok(value_of(k))
                        })
                        .unwrap();
                    observed.lock().unwrap().push((k, *v));
                }
            });
        }
    });
    assert_eq!(computed.load(Ordering::SeqCst), KEYS as usize, "exactly-once compute per key");
    assert_eq!(memo.computed(), KEYS as usize);
    assert_eq!(memo.hits(), THREADS * KEYS as usize - KEYS as usize);
    let observed = observed.lock().unwrap();
    assert_eq!(observed.len(), THREADS * KEYS as usize);
    for (k, v) in observed.iter() {
        assert_eq!(v, &reference[k], "key {k} diverged from the single-lock reference");
    }
}

#[test]
fn distinct_key_computations_overlap_even_within_one_shard() {
    // the bugfix invariant: no shard lock is held while a stage evaluates.
    // Keys 0 and 16 land in the same shard of the 16-way map; were the
    // lock held across the compute, these two slow evaluations would
    // serialize to >= 2x the injected stage latency
    use aladin::dse::ShardedMemo;
    use std::time::{Duration, Instant};

    let memo: ShardedMemo<u64> = ShardedMemo::new();
    let slow = Duration::from_millis(150);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for key in [0u64, 16] {
            let memo = &memo;
            s.spawn(move || {
                memo.get_or_compute(key, || {
                    std::thread::sleep(slow);
                    Ok(key + 1)
                })
                .unwrap();
            });
        }
    });
    let elapsed = t0.elapsed();
    assert_eq!(memo.computed(), 2);
    assert!(
        elapsed < slow * 2,
        "same-shard evaluations must overlap, took {elapsed:?} for 2x {slow:?} stages"
    );
}

#[test]
fn engines_sharing_one_cache_replay_each_others_stages() {
    // the serve topology in miniature: two independent engines built on
    // one SharedCache — the second engine's identical job is served from
    // the first one's stages, and the per-job story is told by the
    // delta_since snapshots
    use aladin::dse::SharedCache;
    let cache = SharedCache::new();
    let vector = DesignVector {
        quant: Some(QuantAxis::uniform(4, BlockImpl::Im2col, 10)),
        hw: Some(HwAxis { cores: 4, l2_kb: 320, backend: None }),
    };
    let a = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
        .with_cache(cache.clone());
    let before = a.stats();
    let r0 = a.evaluate(&vector).unwrap();
    let cold = a.stats().delta_since(&before);
    assert_eq!(cold.impl_computed, 1);
    assert_eq!(cold.sim_computed, 1);
    assert_eq!(cold.sim_hits, 0);

    let b = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
        .with_cache(cache.clone());
    let before = b.stats();
    let r1 = b.evaluate(&vector).unwrap();
    let warm = b.stats().delta_since(&before);
    assert_eq!(warm.impl_computed, 0, "second engine must not re-decorate");
    assert_eq!(warm.sim_computed, 0, "second engine must not re-simulate");
    assert_eq!(warm.impl_hits, 1);
    assert_eq!(warm.sim_hits, 1);
    assert_records_bit_identical(&r0, &r1);
}

#[test]
fn grid_search_results_unchanged_by_engine_port() {
    // the ported GridSearch must agree with a hand-driven Pipeline run
    let (g, cfg) = small(models::case2()).build();
    let points = GridSearch::fig7(presets::gap8())
        .run_canonical(g.clone(), &cfg)
        .unwrap();
    for p in &points {
        let direct = aladin::coordinator::Pipeline::new(
            presets::gap8_with(p.cores, p.l2_kb),
            cfg.clone(),
        )
        .analyze(g.clone())
        .unwrap();
        assert_eq!(p.total_cycles, direct.latency.total_cycles, "c{} l2 {}", p.cores, p.l2_kb);
        assert_eq!(p.sim.layers.len(), direct.sim.layers.len());
    }
}
