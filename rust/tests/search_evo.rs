//! Integration tests for the evolutionary multi-objective searcher
//! (`dse::search`): seeded determinism across engine thread counts, front
//! quality against the enumerable exhaustive ground truth, soundness of
//! the lower-bound pruning, and scalability to spaces far beyond
//! enumeration under a bounded evaluation budget.

use aladin::dse::{
    evolve, explore_joint, objectives, EvalEngine, EvoConfig, EvoResult, JointSpace, PruneReason,
    SearchSpace,
};
use aladin::models::{self, BlockImpl, MobileNetConfig};
use aladin::platform::presets;
use std::sync::Arc;

fn small(mut case: MobileNetConfig) -> MobileNetConfig {
    case.width_mult = 0.25; // keep integration runs fast
    case
}

fn dominates_or_equals(a: &[f64; 4], b: &[f64; 4]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y)
}

fn strictly_dominates(a: &[f64; 4], b: &[f64; 4]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
}

fn assert_front_mutually_nondominated(r: &EvoResult) {
    for &i in &r.front {
        for &j in &r.front {
            if i == j {
                continue;
            }
            let (a, b) = (objectives(&r.records[i]), objectives(&r.records[j]));
            assert!(!strictly_dominates(&a, &b), "front member {i} dominates {j}");
        }
    }
}

#[test]
fn evo_front_dominates_or_equals_exhaustive_on_fig7_grid() {
    // a single quantization configuration × the Fig. 7 hardware grid: the
    // space is enumerable, so the exhaustive front is ground truth. The
    // seeded generation 0 covers the whole uniform sub-grid, so the final
    // evolutionary front must dominate-or-equal every exhaustive point.
    let space = SearchSpace {
        bits: vec![8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![2, 4, 8],
        l2_kb: vec![256, 320, 512],
        backends: vec![],
    };
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let cfg = EvoConfig {
        population: 12,
        generations: 3,
        seed: 7,
        ..EvoConfig::default()
    };
    let evo = evolve(&engine, &space, &cfg).unwrap();
    assert!(!evo.front.is_empty());
    assert_front_mutually_nondominated(&evo);

    let jspace = JointSpace {
        bits: vec![8],
        impls: vec![BlockImpl::Im2col],
        tail_k: 0,
        cores: vec![2, 4, 8],
        l2_kb: vec![256, 320, 512],
        backends: vec![],
    };
    let exh = explore_joint(small(models::case2()), presets::gap8(), &jspace, Some(2)).unwrap();
    assert!(!exh.front.is_empty());
    for &fi in &exh.front {
        let target = objectives(&exh.records[fi]);
        assert!(
            evo.front
                .iter()
                .any(|&i| dominates_or_equals(&objectives(&evo.records[i]), &target)),
            "exhaustive front point {fi} not dominated-or-equalled by the evo front"
        );
    }
}

#[test]
fn evo_front_covers_exhaustive_uniform_quant_grid() {
    // the default joint grid (2 uniform quant configs × 9 hardware points)
    // embeds in the per-layer space; the uniform seeds guarantee those 18
    // candidates are all in the archive, so the evo front must
    // dominate-or-equal the exhaustive front of the embedded grid.
    let space = SearchSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![2, 4, 8],
        l2_kb: vec![256, 320, 512],
        backends: vec![],
    };
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let cfg = EvoConfig {
        population: 24,
        generations: 2,
        seed: 13,
        ..EvoConfig::default()
    };
    let evo = evolve(&engine, &space, &cfg).unwrap();

    let exh = explore_joint(
        small(models::case2()),
        presets::gap8(),
        &JointSpace::default_grid(),
        Some(2),
    )
    .unwrap();
    for &fi in &exh.front {
        let target = objectives(&exh.records[fi]);
        assert!(
            evo.front
                .iter()
                .any(|&i| dominates_or_equals(&objectives(&evo.records[i]), &target)),
            "embedded uniform-grid front point {fi} not covered"
        );
    }
}

#[test]
fn seeded_search_is_bit_identical_across_thread_counts() {
    let space = SearchSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
        backends: vec![],
    };
    let run = |threads: usize| -> EvoResult {
        let engine = EvalEngine::for_mobilenet(small(models::case1()), presets::gap8())
            .with_threads(threads);
        let cfg = EvoConfig {
            population: 10,
            generations: 3,
            max_evals: 60,
            seed: 42,
            ..EvoConfig::default()
        };
        evolve(&engine, &space, &cfg).unwrap()
    };
    let a = run(1);
    let b = run(8);
    let signature = |r: &EvoResult| -> Vec<(String, u64, u64, u64, u64)> {
        r.records
            .iter()
            .map(|x| {
                (
                    x.quant_label(),
                    x.total_cycles,
                    x.sensitivity.to_bits(),
                    x.mem_kb.to_bits(),
                    x.energy_nj.to_bits(),
                )
            })
            .collect()
    };
    assert_eq!(a.evaluations, b.evaluations);
    assert_eq!(signature(&a), signature(&b), "archive differs across thread counts");
    assert_eq!(a.front, b.front, "final front differs across thread counts");
    for (&i, &j) in a.front.iter().zip(&b.front) {
        assert_eq!(
            objectives(&a.records[i]).map(f64::to_bits),
            objectives(&b.records[j]).map(f64::to_bits)
        );
    }
    // the per-generation trajectory is deterministic too
    let gens = |r: &EvoResult| -> Vec<(usize, usize, u64)> {
        r.generations
            .iter()
            .map(|g| (g.evaluated, g.front_size, g.hypervolume.to_bits()))
            .collect()
    };
    assert_eq!(gens(&a), gens(&b));
}

#[test]
fn evo_scales_to_a_million_point_space_under_budget() {
    // acceptance criterion: a per-layer space of >= 10^6 candidates
    // completes under a bounded evaluation budget (<= 2000, here far less)
    let space = SearchSpace {
        bits: vec![2, 4, 8],
        impls: vec![BlockImpl::Im2col, BlockImpl::Lut],
        n_blocks: 10,
        cores: vec![2, 4, 8],
        l2_kb: vec![256, 320, 512],
        backends: vec![],
    };
    assert!(space.size() >= 1e6, "space too small: {}", space.size());
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let cfg = EvoConfig {
        population: 16,
        generations: 6,
        max_evals: 120,
        seed: 3,
        ..EvoConfig::default()
    };
    let r = evolve(&engine, &space, &cfg).unwrap();
    assert!(r.evaluations <= 120, "budget exceeded: {}", r.evaluations);
    assert_eq!(r.evaluations, r.records.len());
    assert!(!r.front.is_empty());
    assert_front_mutually_nondominated(&r);
    assert!(!r.generations.is_empty());
    for g in &r.generations {
        assert!(g.hypervolume.is_finite() && g.hypervolume >= 0.0);
        assert!(g.evaluated <= cfg.max_evals);
    }
    // mixed per-layer genomes actually appear (the space is not uniform)
    assert!(
        r.records.iter().any(|x| {
            x.vector
                .quant
                .as_ref()
                .map(|q| q.bits.windows(2).any(|w| w[0] != w[1]))
                .unwrap_or(false)
        }),
        "no mixed-precision genome was ever evaluated"
    );
}

#[test]
fn bound_pruned_candidates_could_not_enter_the_front() {
    // acceptance criterion: pruning is sound — re-evaluating every
    // bound-pruned candidate in full, each is dominated-or-equalled by the
    // final front, and the bound never exceeded the true cycles.
    let space = SearchSpace {
        bits: vec![2, 4, 8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
        backends: vec![],
    };
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let cfg = EvoConfig {
        population: 16,
        generations: 5,
        max_evals: 100,
        seed: 5,
        ..EvoConfig::default()
    };
    let r = evolve(&engine, &space, &cfg).unwrap();
    let front_objs: Vec<[f64; 4]> = r.front.iter().map(|&i| objectives(&r.records[i])).collect();
    let bound_pruned = r
        .pruned
        .iter()
        .filter(|(_, why)| matches!(why, PruneReason::Bound { .. }))
        .count();
    let mut checked = 0usize;
    for (genome, reason) in &r.pruned {
        let PruneReason::Bound { lb_cycles } = reason else {
            continue;
        };
        let full = engine.evaluate(&genome.vector()).unwrap();
        assert!(
            *lb_cycles <= full.total_cycles,
            "{}: bound {lb_cycles} > true cycles {}",
            genome.label(),
            full.total_cycles
        );
        let obj = objectives(&full);
        assert!(
            front_objs.iter().any(|f| dominates_or_equals(f, &obj)),
            "pruned candidate {} would have entered the front",
            genome.label()
        );
        checked += 1;
    }
    assert_eq!(checked, bound_pruned);
}

#[test]
fn measured_search_with_successive_halving_refines_survivors() {
    let space = SearchSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
        backends: vec![],
    };
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
        .with_measured_accuracy(Arc::new(models::cifar_vectors(8)));
    let cfg = EvoConfig {
        population: 8,
        generations: 2,
        max_evals: 24,
        seed: 9,
        screen_vectors: 2,
        ..EvoConfig::default()
    };
    let r = evolve(&engine, &space, &cfg).unwrap();
    assert!(r.measured);
    assert!(!r.front.is_empty());
    assert!(r.records.iter().all(|x| x.accuracy.is_some()));
    for &i in &r.front {
        let a = r.records[i].accuracy.unwrap();
        assert!((0.0..=1.0).contains(&a));
    }
    // the screen tier really ran the interpreter on fewer vectors: the
    // accuracy stage computed both tiers but the totals stay bounded by
    // (distinct quant genomes) x 2
    assert!(r.stats.acc_computed >= 1);
}

#[test]
fn seeded_front_identical_with_delta_path_on_and_off_across_threads() {
    // acceptance criterion for the layer-grained delta path: a seeded evo
    // run produces the same archive and front with the delta path enabled
    // and disabled, on 1 and 8 engine threads — incremental evaluation is
    // bit-identical, not merely close
    let space = SearchSpace {
        bits: vec![2, 4, 8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
        backends: vec![],
    };
    let run = |threads: usize, delta: bool| -> EvoResult {
        let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
            .with_threads(threads);
        let cfg = EvoConfig {
            population: 10,
            generations: 3,
            max_evals: 60,
            seed: 99,
            delta,
            ..EvoConfig::default()
        };
        evolve(&engine, &space, &cfg).unwrap()
    };
    let signature = |r: &EvoResult| -> Vec<(String, usize, u64, u64, u64, u64, u64)> {
        r.records
            .iter()
            .map(|x| {
                (
                    x.quant_label(),
                    x.cores,
                    x.l2_kb,
                    x.total_cycles,
                    x.sensitivity.to_bits(),
                    x.mem_kb.to_bits(),
                    x.energy_nj.to_bits(),
                )
            })
            .collect()
    };
    let reference = run(1, true);
    assert!(reference.evaluations > 0);
    for (threads, delta) in [(1usize, false), (8, true), (8, false)] {
        let other = run(threads, delta);
        assert_eq!(
            signature(&reference),
            signature(&other),
            "archive differs (threads {threads}, delta {delta})"
        );
        assert_eq!(
            reference.front, other.front,
            "front differs (threads {threads}, delta {delta})"
        );
    }
}

#[test]
fn backend_gene_4d_front_deterministic_across_threads_and_delta() {
    // satellite criterion for the Backend tentpole: with the backend gene
    // active, the 4-objective (sensitivity, latency, memory, energy)
    // search stays bit-identical across 1/8 engine threads and with the
    // delta path on and off — and the archive spans all three backends
    // (generation-0 seeds enumerate the gene)
    use aladin::sim::BackendKind;
    let space = SearchSpace {
        bits: vec![4, 8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![2, 8],
        l2_kb: vec![256, 512],
        backends: BackendKind::all().to_vec(),
    };
    let run = |threads: usize, delta: bool| -> EvoResult {
        let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
            .with_threads(threads);
        let cfg = EvoConfig {
            population: 18,
            generations: 3,
            max_evals: 90,
            seed: 17,
            delta,
            ..EvoConfig::default()
        };
        evolve(&engine, &space, &cfg).unwrap()
    };
    let reference = run(1, true);
    assert!(!reference.front.is_empty());
    assert_front_mutually_nondominated(&reference);
    let labels: std::collections::BTreeSet<&str> =
        reference.records.iter().map(|r| r.sim.backend.as_str()).collect();
    assert_eq!(labels.len(), 3, "archive must span all three backends: {labels:?}");
    // energy is a real fourth axis, not a relabeling of latency
    let energies: std::collections::BTreeSet<u64> =
        reference.records.iter().map(|r| r.energy_nj.to_bits()).collect();
    assert!(energies.len() > 1, "energy axis is constant across the archive");

    let signature = |r: &EvoResult| -> Vec<(String, usize, u64, String, u64, u64)> {
        r.records
            .iter()
            .map(|x| {
                (
                    x.quant_label(),
                    x.cores,
                    x.l2_kb,
                    x.sim.backend.clone(),
                    x.total_cycles,
                    x.energy_nj.to_bits(),
                )
            })
            .collect()
    };
    for (threads, delta) in [(1usize, false), (8, true), (8, false)] {
        let other = run(threads, delta);
        assert_eq!(
            signature(&reference),
            signature(&other),
            "archive differs (threads {threads}, delta {delta})"
        );
        assert_eq!(
            reference.front, other.front,
            "front differs (threads {threads}, delta {delta})"
        );
        for (&i, &j) in reference.front.iter().zip(&other.front) {
            assert_eq!(
                objectives(&reference.records[i]).map(f64::to_bits),
                objectives(&other.records[j]).map(f64::to_bits)
            );
        }
    }
}
