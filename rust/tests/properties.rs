//! Property-based tests over randomized inputs (in-tree harness,
//! `aladin::util::check_property`): coordinator invariants — tiling
//! feasibility/coverage, decoration equations, quantizer equivalences,
//! simulator monotonicity, and parser round-trips.

use aladin::graph::builder::GraphBuilder;
use aladin::graph::ir::ConvAttrs;
use aladin::graph::tensor::{ElemType, TensorSpec};
use aladin::impl_aware::{decorate, ImplConfig, NodeImplSpec};
use aladin::platform::presets;
use aladin::platform_aware::{build_schedule, fuse, plan_layer};
use aladin::quant::{DyadicScale, MulLut, ThresholdTree, UniformQuantizer};
use aladin::sim::simulate;
use aladin::util::json::Value;
use aladin::util::prng::{check_property, Prng};
use aladin::util::yamlish;

/// Random small conv net decorated with a random implementation config.
fn random_decorated(rng: &mut Prng) -> aladin::graph::ir::Graph {
    let cin = rng.range(1, 16);
    let hw = [4, 8, 16, 32][rng.range(0, 3)];
    let cout = rng.range(1, 64);
    let bits = [2u8, 4, 8][rng.range(0, 2)];
    let k = [1usize, 3][rng.range(0, 1)];
    let stride = rng.range(1, 2).min(hw / 2).max(1);
    let depthwise = rng.chance(0.3) && cin > 1;

    let mut b = GraphBuilder::new(
        "rand",
        TensorSpec::chw(cin, hw, hw, ElemType::int(8)),
        ElemType::int(if bits < 8 { 16 } else { 32 }),
    );
    let attrs = if depthwise {
        ConvAttrs::depthwise(cin, 3, stride, 1)
    } else {
        ConvAttrs::standard(cout, k, stride, if k == 3 { 1 } else { 0 })
    };
    b.conv("c", attrs, ElemType::int(bits))
        .relu("r")
        .quant("q", ElemType::int(bits), rng.chance(0.5));
    let g = b.finish();

    let mut cfg = ImplConfig::default();
    let impls = ["im2col", "lut", "direct"];
    cfg.set_node(
        "c",
        NodeImplSpec {
            implementation: Some(impls[rng.range(0, 2)].into()),
            ..Default::default()
        },
    );
    let qimpls = ["dyadic", "thresholds"];
    cfg.set_node(
        "q",
        NodeImplSpec {
            implementation: Some(qimpls[rng.range(0, 1)].into()),
            ..Default::default()
        },
    );
    decorate(g, &cfg).unwrap()
}

#[test]
fn prop_tiling_always_fits_l1_and_covers_output() {
    check_property("tiling_fits_l1", 200, |rng| {
        let g = random_decorated(rng);
        let layers = fuse(&g).unwrap();
        let mut platform = presets::gap8();
        // randomized L1 capacity (power-of-two banks)
        platform.l1_banks = 16;
        platform.l1_bytes = [16u64, 32, 64, 128][rng.range(0, 3)] * 1024;
        for layer in &layers {
            match plan_layer(layer, &platform) {
                Ok(plan) => {
                    assert!(
                        plan.l1_used_bytes <= platform.l1_bytes,
                        "{}: used {} > L1 {}",
                        layer.name,
                        plan.l1_used_bytes,
                        platform.l1_bytes
                    );
                    // tiles cover the whole output
                    let out_total = plan.tile_output_bytes * plan.n_tiles() as u64;
                    assert!(out_total * 8 >= layer.output_bits);
                    assert!(plan.n_tiles() >= 1);
                }
                Err(aladin::AladinError::Infeasible { .. }) => {} // legal outcome
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
    });
}

#[test]
fn prop_decoration_eq6_bops_relation() {
    check_property("eq6_bops", 200, |rng| {
        let g = random_decorated(rng);
        for n in &g.nodes {
            if let (Some(ann), true) = (&n.ann, n.op.is_linear()) {
                if ann.macs > 0 {
                    // BOPs divisible by MACs with quotient 1 + Lacc + Lw + Lx
                    assert_eq!(ann.bops % ann.macs, 0, "{}", n.name);
                    let q = ann.bops / ann.macs;
                    assert!(q > 1 && q <= 1 + 32 + 8 + 8, "{}: q={q}", n.name);
                }
            }
        }
    });
}

#[test]
fn prop_memory_monotone_in_weight_bits() {
    check_property("mem_monotone_bits", 100, |rng| {
        let cin = rng.range(1, 8);
        let cout = rng.range(1, 32);
        let hw = 8;
        let build = |bits: u8| {
            let mut b = GraphBuilder::new(
                "m",
                TensorSpec::chw(cin, hw, hw, ElemType::int(8)),
                ElemType::int(32),
            );
            b.conv("c", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(bits));
            decorate(b.finish(), &ImplConfig::default()).unwrap()
        };
        let m2 = build(2).total_param_bits();
        let m4 = build(4).total_param_bits();
        let m8 = build(8).total_param_bits();
        assert!(m2 <= m4 && m4 <= m8, "{m2} {m4} {m8}");
    });
}

#[test]
fn prop_dyadic_scale_accuracy() {
    check_property("dyadic_accuracy", 500, |rng| {
        let scale = rng.uniform(1e-6, 8.0);
        let d = DyadicScale::fit(scale, 31);
        assert!(
            d.rel_error(scale) < 1e-5,
            "scale={scale} err={}",
            d.rel_error(scale)
        );
        // apply() tracks the float rescale within 1 ulp
        let acc = rng.range_i64(-1_000_000, 1_000_000);
        let want = (acc as f64 * scale).round() as i64;
        assert!((d.apply(acc) - want).abs() <= 1, "acc={acc} scale={scale}");
    });
}

#[test]
fn prop_threshold_tree_equals_uniform_quantizer() {
    check_property("tree_vs_uniform", 300, |rng| {
        let bits = [2u8, 3, 4, 8][rng.range(0, 3)];
        let scale = rng.uniform(0.5, 2000.0);
        let out = ElemType::int(bits);
        let tree = ThresholdTree::from_uniform_scale(scale, ElemType::int(32), out);
        for _ in 0..32 {
            let acc = rng.range_i64(-5_000_000, 5_000_000);
            let uniform = out.clamp((acc as f64 / scale).round() as i64);
            assert_eq!(tree.apply(acc), uniform, "acc={acc} scale={scale} bits={bits}");
        }
    });
}

#[test]
fn prop_mul_lut_exact_for_all_bit_combos() {
    for w_bits in [2u8, 3, 4] {
        for x_bits in [2u8, 4, 8] {
            let lut = MulLut::build(
                ElemType::int(w_bits),
                ElemType::int(x_bits),
                ElemType::int(32),
            );
            let wt = ElemType::int(w_bits);
            let xt = ElemType::int(x_bits);
            for w in wt.min_value()..=wt.max_value() {
                for x in xt.min_value()..=xt.max_value() {
                    assert_eq!(lut.mul(w, x), w * x);
                }
            }
        }
    }
}

#[test]
fn prop_quantize_dequantize_error_bounded() {
    check_property("quant_error_bound", 300, |rng| {
        let bits = [2u8, 4, 8][rng.range(0, 2)];
        let beta = rng.uniform(0.1, 100.0);
        let q = UniformQuantizer::symmetric(beta, ElemType::int(bits));
        let r = rng.uniform(-beta, beta);
        assert!(q.error(r) <= q.scale / 2.0 + 1e-9, "r={r} beta={beta} bits={bits}");
    });
}

#[test]
fn prop_sim_cycles_monotone_in_cores() {
    check_property("sim_monotone_cores", 60, |rng| {
        let g = random_decorated(rng);
        let layers = fuse(&g).unwrap();
        let mut prev = u64::MAX;
        for cores in [1usize, 2, 4, 8] {
            let p = presets::gap8_with(cores, 512);
            // an oversized LUT can legitimately be L1-infeasible
            let s = match build_schedule(&layers, &std::sync::Arc::new(p)) {
                Ok(s) => s,
                Err(aladin::AladinError::Infeasible { .. }) => return,
                Err(e) => panic!("unexpected error: {e}"),
            };
            let cycles = simulate(&s).total_cycles();
            assert!(
                cycles <= prev,
                "cores {cores}: {cycles} > prev {prev}"
            );
            prev = cycles;
        }
    });
}

#[test]
fn prop_sim_cycles_monotone_in_l2() {
    // satellite regression: growing L2 (more residency, fewer refetches,
    // more prefetch hiding) never slows a layer down
    check_property("sim_monotone_l2", 60, |rng| {
        let g = random_decorated(rng);
        let layers = fuse(&g).unwrap();
        let mut prev = u64::MAX;
        for l2_kb in [128u64, 256, 512, 1024] {
            let p = presets::gap8_with(8, l2_kb);
            let s = match build_schedule(&layers, &std::sync::Arc::new(p)) {
                Ok(s) => s,
                Err(aladin::AladinError::Infeasible { .. }) => return,
                Err(e) => panic!("unexpected error: {e}"),
            };
            let cycles = simulate(&s).total_cycles();
            assert!(cycles <= prev, "L2 {l2_kb}kB: {cycles} > prev {prev}");
            prev = cycles;
        }
    });
}

#[test]
fn prop_sim_conservation() {
    // per-layer: the exposed decomposition is exact — compute + exposed
    // dma-l1 + exposed dma-l3 == cycles — and prefetch hiding never
    // exceeds the previous layer's micro-DMA-free window
    check_property("sim_conservation", 100, |rng| {
        let g = random_decorated(rng);
        let s = match build_schedule(&fuse(&g).unwrap(), &std::sync::Arc::new(presets::gap8())) {
            Ok(s) => s,
            Err(aladin::AladinError::Infeasible { .. }) => return,
            Err(e) => panic!("unexpected error: {e}"),
        };
        let r = simulate(&s);
        for l in &r.layers {
            assert!(l.cycles >= l.compute_cycles, "{}", l.name);
            assert_eq!(l.stall_cycles, l.cycles - l.compute_cycles);
            assert_eq!(
                l.compute_cycles + l.exposed_dma_l1_cycles + l.exposed_dma_l3_cycles,
                l.cycles,
                "{}",
                l.name
            );
            assert_eq!(
                l.exposed_dma_l3_cycles + l.hidden_dma_l3_cycles,
                l.dma_l3_cycles,
                "{}",
                l.name
            );
        }
        for w in r.layers.windows(2) {
            assert!(
                w[1].hidden_dma_l3_cycles <= w[0].cycles - w[0].exposed_dma_l3_cycles,
                "{}: prefetch overbooked the micro-DMA channel",
                w[1].name
            );
        }
        let u = r.compute_utilization();
        assert!(u > 0.0 && u <= 1.0);
    });
}

#[test]
fn prop_lower_bound_never_exceeds_sim() {
    // satellite regression: the analytic ideal-overlap lower bound
    // (sim::lower_bound_cycles, the DSE search's pruning stage) must be
    // sound on the random-layer corpus — never above the full timeline
    check_property("lower_bound_sound", 100, |rng| {
        let g = random_decorated(rng);
        let layers = fuse(&g).unwrap();
        let cores = [1usize, 2, 4, 8][rng.range(0, 3)];
        let l2_kb = [128u64, 256, 512][rng.range(0, 2)];
        let p = presets::gap8_with(cores, l2_kb);
        let s = match build_schedule(&layers, &std::sync::Arc::new(p)) {
            Ok(s) => s,
            Err(aladin::AladinError::Infeasible { .. }) => return,
            Err(e) => panic!("unexpected error: {e}"),
        };
        let bound = aladin::sim::lower_bound_cycles(&s);
        let sim = simulate(&s).total_cycles();
        assert!(
            bound <= sim,
            "bound {bound} > simulated {sim} (cores {cores}, L2 {l2_kb} kB)"
        );
        // and it is not vacuous: at least the compute-busy time
        assert!(bound > 0);
    });
}

#[test]
fn prop_lower_bound_sound_per_backend() {
    // the pruning bound must stay sound no matter which backend owns the
    // within-layer pipeline; cores >= 2 so the sharded backend validates
    check_property("lower_bound_sound_backends", 60, |rng| {
        let g = random_decorated(rng);
        let layers = fuse(&g).unwrap();
        let cores = [2usize, 4, 8][rng.range(0, 2)];
        let l2_kb = [128u64, 256, 512][rng.range(0, 2)];
        for kind in aladin::sim::BackendKind::all() {
            let mut p = presets::gap8_with(cores, l2_kb);
            p.backend = kind;
            let s = match build_schedule(&layers, &std::sync::Arc::new(p)) {
                Ok(s) => s,
                Err(aladin::AladinError::Infeasible { .. }) => continue,
                Err(e) => panic!("unexpected error: {e}"),
            };
            let bound = aladin::sim::lower_bound_cycles(&s);
            let sim = simulate(&s).total_cycles();
            assert!(
                bound <= sim,
                "{}: bound {bound} > simulated {sim} (cores {cores}, L2 {l2_kb} kB)",
                kind.label()
            );
            assert!(bound > 0, "{}", kind.label());
        }
    });
}

#[test]
fn prop_energy_monotone_nonincreasing_in_bits_per_backend() {
    // the QAPPA-style energy model: every term shrinks (or stays constant)
    // as operand bit widths shrink, under every backend's cost set
    check_property("energy_monotone_bits", 100, |rng| {
        let cin = rng.range(1, 8);
        let cout = rng.range(1, 32);
        let hw = [4usize, 8, 16][rng.range(0, 2)];
        let build = |bits: u8| {
            let mut b = GraphBuilder::new(
                "e",
                TensorSpec::chw(cin, hw, hw, ElemType::int(8)),
                ElemType::int(32),
            );
            b.conv("c", ConvAttrs::standard(cout, 3, 1, 1), ElemType::int(bits))
                .relu("r")
                .quant("q", ElemType::int(bits), false);
            let mut cfg = ImplConfig::default();
            cfg.set_node(
                "c",
                NodeImplSpec {
                    implementation: Some("im2col".into()),
                    ..Default::default()
                },
            );
            fuse(&decorate(b.finish(), &cfg).unwrap()).unwrap()
        };
        let (l2, l4, l8) = (build(2), build(4), build(8));
        for kind in aladin::sim::BackendKind::all() {
            let mut p = presets::gap8();
            p.backend = kind;
            let e2 = aladin::sim::model_energy_nj(&l2, &p);
            let e4 = aladin::sim::model_energy_nj(&l4, &p);
            let e8 = aladin::sim::model_energy_nj(&l8, &p);
            assert!(
                e2 <= e4 && e4 <= e8,
                "{}: {e2} {e4} {e8}",
                kind.label()
            );
            assert!(e2 > 0.0 && e8.is_finite(), "{}", kind.label());
        }
    });
}

#[test]
fn prop_pareto_2d_fast_path_agrees() {
    // satellite regression: the O(n log n) 2-objective sweep must agree
    // with the O(n^2) scan on random inputs (ties and clusters included)
    fn naive_2d(points: &[[f64; 2]]) -> Vec<usize> {
        let dom = |a: &[f64; 2], b: &[f64; 2]| {
            a.iter().zip(b.iter()).all(|(x, y)| x <= y)
                && a.iter().zip(b.iter()).any(|(x, y)| x < y)
        };
        (0..points.len())
            .filter(|&i| {
                !points
                    .iter()
                    .enumerate()
                    .any(|(j, p)| j != i && dom(p, &points[i]))
            })
            .collect()
    }
    check_property("pareto_2d_agrees", 300, |rng| {
        let n = rng.range(0, 40);
        // a small value alphabet forces plenty of exact ties
        let pts: Vec<[f64; 2]> = (0..n)
            .map(|_| {
                [
                    rng.range_i64(0, 6) as f64 / 2.0,
                    rng.range_i64(0, 6) as f64 / 2.0,
                ]
            })
            .collect();
        assert_eq!(
            aladin::dse::pareto_min_2d(&pts),
            naive_2d(&pts),
            "pts={pts:?}"
        );
    });
}

#[test]
fn prop_pareto_constant_axis_fast_path_agrees() {
    // the 3-objective front with one constant axis must match the generic
    // all-pairs scan (it internally collapses to the 2-D sweep)
    fn naive_3d(points: &[[f64; 3]]) -> Vec<usize> {
        let dom = |a: &[f64; 3], b: &[f64; 3]| {
            a.iter().zip(b.iter()).all(|(x, y)| x <= y)
                && a.iter().zip(b.iter()).any(|(x, y)| x < y)
        };
        (0..points.len())
            .filter(|&i| {
                !points
                    .iter()
                    .enumerate()
                    .any(|(j, p)| j != i && dom(p, &points[i]))
            })
            .collect()
    }
    check_property("pareto_constant_axis_agrees", 200, |rng| {
        let n = rng.range(1, 30);
        let constant_axis = rng.range(0, 2);
        let c = rng.range_i64(-4, 4) as f64;
        let pts: Vec<[f64; 3]> = (0..n)
            .map(|_| {
                let mut p = [
                    rng.range_i64(0, 8) as f64 / 2.0,
                    rng.range_i64(0, 8) as f64 / 2.0,
                    rng.range_i64(0, 8) as f64 / 2.0,
                ];
                p[constant_axis] = c;
                p
            })
            .collect();
        assert_eq!(
            aladin::dse::pareto_min_indices(&pts),
            naive_3d(&pts),
            "pts={pts:?}"
        );
    });
}

#[test]
fn prop_json_round_trip_random_documents() {
    fn random_value(rng: &mut Prng, depth: usize) -> Value {
        match if depth == 0 { rng.range(0, 3) } else { rng.range(0, 5) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Num((rng.range_i64(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => Value::Str(
                (0..rng.range(0, 12))
                    .map(|_| *rng.choice(&['a', 'b', '"', '\\', 'é', '\n', ' ', 'z']))
                    .collect(),
            ),
            4 => Value::Arr((0..rng.range(0, 4)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => {
                let mut o = Value::obj();
                for i in 0..rng.range(0, 4) {
                    o.set(format!("k{i}"), random_value(rng, depth - 1));
                }
                o
            }
        }
    }
    check_property("json_round_trip", 300, |rng| {
        let v = random_value(rng, 3);
        let compact = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(compact, v);
        let pretty = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(pretty, v);
    });
}

#[test]
fn prop_implconfig_yaml_round_trip() {
    check_property("implconfig_round_trip", 100, |rng| {
        let mut cfg = ImplConfig::default();
        for i in 0..rng.range(0, 8) {
            cfg.set_node(
                format!("node_{i}"),
                NodeImplSpec {
                    implementation: if rng.chance(0.8) {
                        Some(["im2col", "lut", "dyadic", "thresholds", "comparator"]
                            [rng.range(0, 4)]
                        .to_string())
                    } else {
                        None
                    },
                    bit_width: if rng.chance(0.5) {
                        Some([2u8, 4, 8][rng.range(0, 2)])
                    } else {
                        None
                    },
                    filter_wise: if rng.chance(0.5) { Some(rng.chance(0.5)) } else { None },
                    num_thresholds: None,
                    bit_shifts: None,
                },
            );
        }
        let text = cfg.to_yaml().unwrap();
        let cfg2 = ImplConfig::from_yaml(&text).unwrap();
        assert_eq!(cfg, cfg2, "yaml:\n{text}");
    });
}

#[test]
fn prop_yamlish_parses_generated_listing1_files() {
    check_property("yamlish_listing1", 100, |rng| {
        let mut text = String::new();
        let n = rng.range(1, 6);
        for i in 0..n {
            text.push_str(&format!("Node_{i}:\n"));
            text.push_str(&format!("  implementation: {}\n", rng.choice(&["lut", "im2col"])));
            if rng.chance(0.5) {
                text.push_str(&format!("  bit_width: {}\n", rng.choice(&[2, 4, 8])));
            }
            if rng.chance(0.3) {
                text.push('\n');
            }
        }
        let v = yamlish::parse(&text).unwrap();
        assert_eq!(v.as_obj().unwrap().len(), n);
    });
}

#[test]
fn prop_spliced_engine_matches_monolithic_pipeline() {
    // tentpole invariant on the random-layer corpus: the engine's
    // layer-grained splice path (cached per-layer units + cross-layer
    // composition) is bit-identical to the monolithic
    // build_schedule + simulate pipeline, and the unit-assembled lower
    // bound equals the schedule-level one
    check_property("spliced_vs_monolithic", 40, |rng| {
        let g = random_decorated(rng);
        let cores = [2usize, 4, 8][rng.range(0, 2)];
        let l2_kb = [256u64, 320, 512][rng.range(0, 2)];
        let engine = aladin::dse::EvalEngine::for_decorated(g.clone(), presets::gap8());
        let v = aladin::dse::DesignVector::of_hw(cores, l2_kb);
        let platform =
            std::sync::Arc::new(presets::gap8().reconfigure(cores, l2_kb * 1024));
        let layers = fuse(&g).unwrap();
        match (engine.evaluate(&v), build_schedule(&layers, &platform)) {
            (Ok(rec), Ok(s)) => {
                let sim = simulate(&s);
                assert_eq!(rec.total_cycles, sim.total_cycles());
                assert_eq!(rec.sim.layers.len(), sim.layers.len());
                for (a, b) in rec.sim.layers.iter().zip(&sim.layers) {
                    assert_eq!(a.cycles, b.cycles, "{}", a.name);
                    assert_eq!(a.compute_cycles, b.compute_cycles, "{}", a.name);
                    assert_eq!(a.exposed_dma_l1_cycles, b.exposed_dma_l1_cycles, "{}", a.name);
                    assert_eq!(a.exposed_dma_l3_cycles, b.exposed_dma_l3_cycles, "{}", a.name);
                    assert_eq!(a.hidden_dma_l3_cycles, b.hidden_dma_l3_cycles, "{}", a.name);
                    assert_eq!(
                        a.compute_cycles + a.exposed_dma_l1_cycles + a.exposed_dma_l3_cycles,
                        a.cycles,
                        "{}",
                        a.name
                    );
                }
                let engine_bound = engine.latency_lower_bound(&v).unwrap();
                assert_eq!(engine_bound, aladin::sim::lower_bound_cycles(&s));
            }
            (Err(_), Err(_)) => {} // both screens agree the corner is infeasible
            (a, b) => panic!("spliced vs monolithic disagree: {a:?} vs {b:?}"),
        }
    });
}

#[test]
fn prop_mutation_chain_delta_bit_identical_to_scratch() {
    // the delta fast path (incremental re-decoration + spliced layer
    // units) over random single- and multi-gene mutation chains must be
    // bit-identical to a from-scratch evaluation on a cold engine —
    // cycles, decomposition fields, peak memories, and tilings
    use aladin::dse::{EvalEngine, SearchSpace};
    use aladin::models::{self, BlockImpl};

    fn assert_bit_identical(a: &aladin::dse::EvalRecord, b: &aladin::dse::EvalRecord) {
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        assert_eq!(a.sensitivity.to_bits(), b.sensitivity.to_bits());
        assert_eq!(a.param_kb.to_bits(), b.param_kb.to_bits());
        assert_eq!(a.mem_kb.to_bits(), b.mem_kb.to_bits());
        assert_eq!(a.peak_l1_kb.to_bits(), b.peak_l1_kb.to_bits());
        assert_eq!(a.peak_l2_kb.to_bits(), b.peak_l2_kb.to_bits());
        assert_eq!(a.l3_traffic_kb.to_bits(), b.l3_traffic_kb.to_bits());
        assert_eq!(a.energy_nj.to_bits(), b.energy_nj.to_bits());
        assert_eq!(a.tilings, b.tilings);
        assert_eq!(a.sim.layers.len(), b.sim.layers.len());
        for (x, y) in a.sim.layers.iter().zip(&b.sim.layers) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.cycles, y.cycles, "{}", x.name);
            assert_eq!(x.compute_cycles, y.compute_cycles, "{}", x.name);
            assert_eq!(x.dma_l1_cycles, y.dma_l1_cycles, "{}", x.name);
            assert_eq!(x.dma_l3_cycles, y.dma_l3_cycles, "{}", x.name);
            assert_eq!(x.exposed_dma_l1_cycles, y.exposed_dma_l1_cycles, "{}", x.name);
            assert_eq!(x.exposed_dma_l3_cycles, y.exposed_dma_l3_cycles, "{}", x.name);
            assert_eq!(x.hidden_dma_l3_cycles, y.hidden_dma_l3_cycles, "{}", x.name);
            assert_eq!(x.l1_used_bytes, y.l1_used_bytes, "{}", x.name);
            assert_eq!(x.l2_used_bytes, y.l2_used_bytes, "{}", x.name);
            assert_eq!(x.n_tiles, y.n_tiles, "{}", x.name);
        }
    }

    check_property("delta_chain_bit_identical", 6, |rng| {
        let mut case = models::case2();
        case.width_mult = 0.25;
        let engine = EvalEngine::for_mobilenet(case.clone(), presets::gap8());
        let space = SearchSpace {
            bits: vec![2, 4, 8],
            impls: vec![BlockImpl::Im2col, BlockImpl::Lut],
            n_blocks: 10,
            cores: vec![2, 4, 8],
            l2_kb: vec![256, 320, 512],
            backends: vec![],
        };
        let mut cur = space.random(rng);
        // seed the base snapshot; an infeasible start is fine (the delta
        // path then falls back to full computation on the next step)
        let _ = engine.evaluate(&cur.vector());
        for _ in 0..3 {
            let mut next = cur.clone();
            space.mutate(&mut next, rng, 0.25);
            let delta = engine.evaluate_delta(&cur.vector(), &next.vector());
            let scratch = EvalEngine::for_mobilenet(case.clone(), presets::gap8())
                .evaluate(&next.vector());
            match (delta, scratch) {
                (Ok(a), Ok(b)) => assert_bit_identical(&a, &b),
                (Err(a), Err(b)) => assert_eq!(a.to_string(), b.to_string()),
                (a, b) => panic!("delta vs scratch disagree: {a:?} vs {b:?}"),
            }
            cur = next;
        }
    });
}
