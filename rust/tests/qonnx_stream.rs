//! Differential tests holding the two JSON front-ends bit-identical: the
//! DOM parser (`Value::parse`) and the streaming pull parser
//! (`util::json::pull`), plus the QONNX decoders built on each
//! (`QonnxModel::from_json` vs `graph::qonnx_stream`).
//!
//! Three suites:
//! - random JSON documents (escapes, unicode, exponents, deep nesting)
//!   must produce identical `Value` trees and identical re-serializations
//!   on both paths;
//! - random QONNX-dialect documents must decode to equal models across
//!   the DOM path and every streaming [`DataPolicy`];
//! - a malformed corpus (truncations, bad escapes, depth bombs, duplicate
//!   keys, overlong numbers, bad payloads) must error — never panic — on
//!   both paths.

use aladin::graph::qonnx::{QonnxModel, QonnxNode, QonnxTensor, TensorData};
use aladin::graph::qonnx_stream::{self, DataPolicy};
use aladin::util::json::{pull, Value};
use aladin::util::prng::{check_property, Prng};
use std::collections::HashMap;

// ---- random document generators ---------------------------------------------

/// Random string stressing the escape and unicode paths: quotes,
/// backslashes, control characters, multi-byte code points.
fn random_string(rng: &mut Prng) -> String {
    let len = rng.range(0, 12);
    let mut s = String::new();
    for _ in 0..len {
        match rng.range(0, 9) {
            0 => s.push('"'),
            1 => s.push('\\'),
            2 => s.push('\n'),
            3 => s.push('\t'),
            4 => s.push('\u{1}'),
            5 => s.push('é'),
            6 => s.push('\u{1F600}'),
            _ => s.push(char::from(b'a' + rng.range(0, 25) as u8)),
        }
    }
    s
}

/// Random number whose decimal round-trip is exact: integers, dyadic
/// fractions, and power-of-two exponent scalings.
fn random_num(rng: &mut Prng) -> f64 {
    match rng.range(0, 3) {
        0 => rng.range_i64(-1_000_000, 1_000_000) as f64,
        1 => rng.range_i64(-4096, 4096) as f64 / 8.0,
        2 => rng.range_i64(-100, 100) as f64 * 1e6,
        _ => rng.range_i64(0, 1) as f64 * 0.5,
    }
}

fn random_value(rng: &mut Prng, depth: usize) -> Value {
    let scalar = depth == 0 || rng.chance(0.4);
    if scalar {
        match rng.range(0, 3) {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Num(random_num(rng)),
            _ => Value::Str(random_string(rng)),
        }
    } else if rng.chance(0.5) {
        let n = rng.range(0, 4);
        Value::Arr((0..n).map(|_| random_value(rng, depth - 1)).collect())
    } else {
        let n = rng.range(0, 4);
        Value::Obj(
            (0..n)
                // index prefix keeps keys unique (both parsers reject dups)
                .map(|i| (format!("k{i}_{}", random_string(rng)), random_value(rng, depth - 1)))
                .collect(),
        )
    }
}

/// Random QONNX-dialect model. Op names and wiring are arbitrary — the
/// decoders under test do not validate graph semantics, only document
/// structure.
fn random_model(rng: &mut Prng) -> QonnxModel {
    let nt = rng.range(1, 4);
    let tensors: Vec<QonnxTensor> = (0..nt)
        .map(|i| {
            let dims: Vec<usize> = (0..rng.range(1, 3)).map(|_| rng.range(1, 4)).collect();
            let data = if rng.chance(0.5) {
                let n: usize = dims.iter().product();
                Some(TensorData::Inline(
                    (0..n).map(|_| rng.range_i64(-128, 127)).collect(),
                ))
            } else {
                None
            };
            QonnxTensor {
                name: format!("t{i}_{}", random_string(rng)),
                dims,
                bits: *rng.choice(&[2u8, 4, 8, 16]),
                signed: rng.chance(0.8),
                initializer: rng.chance(0.5),
                data,
            }
        })
        .collect();
    let nn = rng.range(0, 3);
    let nodes: Vec<QonnxNode> = (0..nn)
        .map(|i| {
            let mut attributes = HashMap::new();
            for a in 0..rng.range(0, 3) {
                attributes.insert(format!("a{a}_{}", random_string(rng)), random_value(rng, 2));
            }
            QonnxNode {
                name: format!("n{i}_{}", random_string(rng)),
                op_type: rng.choice(&["Conv", "Relu", "Quant", "Custom"]).to_string(),
                inputs: vec![tensors[rng.range(0, nt - 1)].name.clone()],
                outputs: vec![tensors[rng.range(0, nt - 1)].name.clone()],
                attributes,
            }
        })
        .collect();
    QonnxModel {
        name: random_string(rng),
        graph_inputs: vec![tensors[0].name.clone()],
        graph_outputs: vec![tensors[nt - 1].name.clone()],
        tensors,
        nodes,
    }
}

// ---- suite 1: DOM vs pull over random JSON ------------------------------------

#[test]
fn pull_and_dom_agree_on_random_documents() {
    check_property("pull_vs_dom_random_json", 300, |rng| {
        let v = random_value(rng, 4);
        let text = if rng.chance(0.5) {
            v.to_string_pretty()
        } else {
            v.to_string_compact()
        };
        let dom = Value::parse(&text).expect("DOM reparse");
        let streamed = pull::to_value(text.as_bytes()).expect("pull reparse");
        assert_eq!(dom, streamed, "value trees diverged for {text}");
        assert_eq!(
            dom.to_string_compact(),
            streamed.to_string_compact(),
            "re-serializations diverged"
        );
        assert_eq!(dom, v, "round-trip lost information for {text}");
    });
}

#[test]
fn pull_and_dom_agree_on_exponent_and_escape_corpus() {
    // raw text the in-memory generator cannot produce: exponent forms,
    // \u escapes (incl. replacement-char fallbacks), mixed whitespace
    let corpus = [
        r#"[1e3, -2.5E-2, 0.125, 1.5e+2, -0e0, 123456789012345]"#,
        r#"{"a": "Aé☃", "b": "\ud83d! \"q\""}"#,
        "\t{ \"x\" :\n[ true,false , null ] }\r\n",
        r#"["\\\\", "\/", "\b\f\n\r\t"]"#,
        r#"[0.0001220703125, 9007199254740991, -9007199254740991]"#,
    ];
    for text in corpus {
        let dom = Value::parse(text).expect("DOM parse");
        let streamed = pull::to_value(text.as_bytes()).expect("pull parse");
        assert_eq!(dom, streamed, "diverged on {text}");
        assert_eq!(dom.to_string_compact(), streamed.to_string_compact());
    }
}

// ---- suite 2: QONNX decoders over random models -------------------------------

#[test]
fn qonnx_decoders_agree_on_random_models() {
    check_property("qonnx_dom_vs_stream", 200, |rng| {
        let model = random_model(rng);
        let text = model.to_json().unwrap().to_string_pretty();

        // the streamed serializer must agree with the DOM serializer too
        let mut streamed_text = Vec::new();
        model.write_pretty(&mut streamed_text).unwrap();
        assert_eq!(text.as_bytes(), &streamed_text[..], "serializers diverged");

        let dom = QonnxModel::from_json(&Value::parse(&text).unwrap()).expect("DOM decode");
        let eager =
            qonnx_stream::from_slice(text.as_bytes(), DataPolicy::Eager).expect("eager decode");
        let lazy =
            qonnx_stream::from_slice(text.as_bytes(), DataPolicy::Lazy).expect("lazy decode");
        assert_eq!(dom, model, "DOM round-trip changed the model");
        assert_eq!(dom, eager, "eager stream diverged from DOM");
        assert_eq!(dom, lazy, "lazy stream diverged from DOM");
        for t in &lazy.tensors {
            if let Some(d) = &t.data {
                assert!(d.is_lazy(), "lazy policy produced inline data");
            }
        }

        let skip =
            qonnx_stream::from_slice(text.as_bytes(), DataPolicy::Skip).expect("skip decode");
        assert!(skip.tensors.iter().all(|t| t.data.is_none()));
        assert_eq!(skip.nodes, dom.nodes);
    });
}

#[test]
fn unknown_keys_are_ignored_identically() {
    check_property("qonnx_unknown_keys", 100, |rng| {
        let model = random_model(rng);
        let mut v = model.to_json().unwrap();
        if let Value::Obj(fields) = &mut v {
            fields.push(("x_doc_extra".into(), random_value(rng, 3)));
            for (key, val) in fields.iter_mut() {
                if key == "tensors" || key == "nodes" {
                    if let Value::Arr(items) = val {
                        for item in items.iter_mut() {
                            if let Value::Obj(f) = item {
                                f.push(("x_extra".into(), random_value(rng, 2)));
                            }
                        }
                    }
                }
            }
        }
        let text = v.to_string_pretty();
        let dom = QonnxModel::from_json(&Value::parse(&text).unwrap()).expect("DOM decode");
        let eager =
            qonnx_stream::from_slice(text.as_bytes(), DataPolicy::Eager).expect("eager decode");
        assert_eq!(dom, model);
        assert_eq!(dom, eager);
    });
}

// ---- suite 3: malformed corpus -------------------------------------------------

/// Both front-ends must report an error (never panic) on `text`.
fn assert_both_reject(text: &str, label: &str) {
    let dom_ok = matches!(
        Value::parse(text).map(|v| QonnxModel::from_json(&v)),
        Ok(Ok(_))
    );
    assert!(!dom_ok, "DOM accepted {label}: {text:.120}");
    for policy in [DataPolicy::Eager, DataPolicy::Lazy, DataPolicy::Skip] {
        assert!(
            qonnx_stream::from_slice(text.as_bytes(), policy).is_err(),
            "stream ({policy:?}) accepted {label}: {text:.120}"
        );
    }
}

#[test]
fn truncated_documents_error_on_both_paths() {
    let model = QonnxModel {
        name: "trunc \"x\"\n".into(),
        graph_inputs: vec!["a".into()],
        graph_outputs: vec!["a".into()],
        tensors: vec![QonnxTensor {
            name: "a".into(),
            dims: vec![2, 2],
            bits: 8,
            signed: true,
            initializer: true,
            data: Some(TensorData::Inline(vec![1, -2, 3, -4])),
        }],
        nodes: vec![QonnxNode {
            name: "n".into(),
            op_type: "Relu".into(),
            inputs: vec!["a".into()],
            outputs: vec!["a".into()],
            attributes: HashMap::new(),
        }],
    };
    let text = model.to_json().unwrap().to_string_pretty();
    // the full document parses on both paths
    assert!(QonnxModel::from_json(&Value::parse(&text).unwrap()).is_ok());
    assert!(qonnx_stream::from_slice(text.as_bytes(), DataPolicy::Eager).is_ok());
    // every strict prefix is malformed: both paths must error, never panic
    for cut in 0..text.len() {
        if !text.is_char_boundary(cut) {
            continue;
        }
        assert_both_reject(&text[..cut], "truncation");
    }
}

#[test]
fn malformed_corpus_errors_on_both_paths() {
    let depth_bomb = "[".repeat(10_000);
    let overlong_number = format!("{{\"name\": {}}}", "1".repeat(65));
    let cases: Vec<(&str, String)> = vec![
        ("bad escape", r#"{"name": "a\qb"}"#.to_string()),
        ("truncated \\u escape", r#"{"name": "\u12"}"#.to_string()),
        ("invalid \\u digits", r#"{"name": "\uZZZZ"}"#.to_string()),
        ("depth bomb", depth_bomb),
        ("duplicate top-level key", r#"{"tensors": [], "tensors": []}"#.to_string()),
        (
            "duplicate tensor key",
            r#"{"graph_inputs": [], "graph_outputs": [], "nodes": [],
               "tensors": [{"name": "t", "name": "t", "dims": [1], "bits": 8}]}"#
                .to_string(),
        ),
        ("overlong number", overlong_number),
        (
            "fractional payload",
            r#"{"graph_inputs": [], "graph_outputs": [], "nodes": [],
               "tensors": [{"name": "t", "dims": [1], "bits": 8, "data": [0.5]}]}"#
                .to_string(),
        ),
        (
            "payload length mismatch",
            r#"{"graph_inputs": [], "graph_outputs": [], "nodes": [],
               "tensors": [{"name": "t", "dims": [3], "bits": 8, "data": [1]}]}"#
                .to_string(),
        ),
        (
            "bits out of range",
            r#"{"graph_inputs": [], "graph_outputs": [], "nodes": [],
               "tensors": [{"name": "t", "dims": [1], "bits": 300}]}"#
                .to_string(),
        ),
        ("non-object root", "[1, 2, 3]".to_string()),
        ("trailing garbage", r#"{"graph_inputs": [], "graph_outputs": [], "tensors": [], "nodes": []} x"#.to_string()),
        ("missing sections", r#"{"name": "only"}"#.to_string()),
        ("mistyped nodes", r#"{"graph_inputs": [], "graph_outputs": [], "tensors": [], "nodes": [42]}"#.to_string()),
    ];
    for (label, text) in &cases {
        assert_both_reject(text, label);
    }
}

#[test]
fn deep_attribute_nesting_errors_identically() {
    // a depth bomb hiding inside a node attribute: both paths must reject
    // it via the shared depth limit, not the process stack
    let bomb = format!(
        r#"{{"graph_inputs": [], "graph_outputs": [], "tensors": [],
            "nodes": [{{"name": "n", "op_type": "Relu",
                        "attributes": {{"deep": {}1{}}}}}]}}"#,
        "[".repeat(5_000),
        "]".repeat(5_000)
    );
    assert_both_reject(&bomb, "attribute depth bomb");
}

#[test]
fn lazy_payload_errors_surface_on_decode() {
    // structurally valid but semantically bad payload: lazy ingest accepts
    // the document (validation deferred), the decode reports the error
    let text = r#"{"graph_inputs": [], "graph_outputs": [], "nodes": [],
                   "tensors": [{"name": "t", "dims": [2], "bits": 8,
                                "data": ["oops", 1]}]}"#;
    assert!(qonnx_stream::from_slice(text.as_bytes(), DataPolicy::Eager).is_err());
    let lazy = qonnx_stream::from_slice(text.as_bytes(), DataPolicy::Lazy).expect("lazy accepts");
    let data = lazy.tensors[0].data.as_ref().expect("span recorded");
    assert!(data.values().is_err(), "bad payload must fail on decode");
}
