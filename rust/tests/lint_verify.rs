//! Soundness tests for the static lint pass (`analysis::verify`) and its
//! DSE screen wiring: the interval analysis must over-approximate the
//! bit-exact interpreter, screen-rejected genomes must be genuinely
//! unevaluable, and a screened evolutionary run must produce a front
//! bit-identical to an unscreened one (the screen only removes candidates
//! that would fail evaluation anyway — the pattern of the bound-pruning
//! soundness test in `search_evo`).

use aladin::analysis::verify::analyze;
use aladin::analysis::{lint_graph, LintConfig, Severity};
use aladin::dse::{evolve, EvalEngine, EvoConfig, EvoResult, Genome, PruneReason, SearchSpace};
use aladin::exec::{measure_batched, measure_scalar, Executable};
use aladin::impl_aware::decorate;
use aladin::models::{self, BlockImpl, MobileNetConfig};
use aladin::platform::presets;
use aladin::sim::BackendKind;
use aladin::util::ToJson;
use std::sync::Arc;

fn small(mut case: MobileNetConfig) -> MobileNetConfig {
    case.width_mult = 0.25; // keep integration runs fast
    case
}

#[test]
fn lint_clean_model_executes_within_predicted_intervals() {
    // acceptance criterion (numeric soundness): a model that lints free of
    // saturation findings runs through the integer interpreter with every
    // activation value inside the statically predicted interval — i.e. the
    // abstract interpretation over-approximates the concrete execution, so
    // "no AL002" really means no unexpected writeback saturation.
    let (g, cfg) = models::lenet(8, (3, 32, 32), 10);
    let decorated = Arc::new(decorate(g, &cfg).unwrap());
    let lint_cfg = LintConfig::default();
    let diags = lint_graph(&decorated, &lint_cfg);
    assert!(
        diags.iter().all(|d| d.severity < Severity::Warn),
        "lenet-int8 must lint clean of warnings/errors: {diags:?}"
    );

    let analysis = analyze(&decorated, &lint_cfg);
    let vectors = models::lenet_vectors(6);
    let exe = Executable::lower(decorated.clone(), &vectors).unwrap();
    let mut checked_edges = 0usize;
    for input in &vectors.inputs {
        let edges = exe.run_int_edges(input).unwrap();
        for (eid, tensor) in edges.iter().enumerate() {
            let (Some(t), Some(iv)) = (tensor, &analysis.edge_intervals[eid]) else {
                continue;
            };
            checked_edges += 1;
            for &v in &t.data {
                assert!(
                    i128::from(v) >= iv.lo && i128::from(v) <= iv.hi,
                    "edge `{}`: concrete value {v} escapes the predicted interval \
                     [{}, {}]",
                    decorated.edges[eid].name,
                    iv.lo,
                    iv.hi
                );
            }
        }
    }
    assert!(checked_edges > 0, "no edge was covered by both paths");

    // the batched executor computes the same deployment bit-for-bit, so
    // the interval soundness extends to exec::batch via the fingerprint
    let scalar = measure_scalar(decorated.clone(), &vectors).unwrap();
    let batched = measure_batched(decorated, &vectors, 4).unwrap();
    assert_eq!(scalar.output_fingerprint, batched.output_fingerprint);
}

/// A search space whose uniform seeds include statically infeasible
/// corners: the sharded backend with a single core fails
/// `PlatformSpec::validate` (lint `AL103`), so the screen has real work.
fn infeasible_seeded_space() -> SearchSpace {
    SearchSpace {
        bits: vec![8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![1, 8],
        l2_kb: vec![256],
        backends: BackendKind::all().to_vec(),
    }
}

#[test]
fn screen_rejected_genomes_are_genuinely_unevaluable() {
    // acceptance criterion (screen soundness): every genome the lint
    // screen rejected is re-driven through the full evaluation path and
    // must fail there too — the screen never removes an evaluable
    // candidate.
    let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8());
    let cfg = EvoConfig {
        population: 12,
        generations: 3,
        max_evals: 60,
        seed: 11,
        ..EvoConfig::default()
    };
    let r = evolve(&engine, &infeasible_seeded_space(), &cfg).unwrap();
    assert!(
        r.stats.lint_rejected > 0,
        "the infeasible-seeded corpus must trip the lint screen: {:?}",
        r.stats
    );
    let mut checked = 0usize;
    for (genome, reason) in &r.pruned {
        let PruneReason::Lint(why) = reason else {
            continue;
        };
        assert!(why.starts_with("AL1"), "screen rejects on platform rules: {why}");
        assert!(
            engine.evaluate(&genome.vector()).is_err(),
            "lint-rejected genome {} evaluated successfully",
            genome.label()
        );
        assert!(
            engine.latency_lower_bound(&genome.vector()).is_err(),
            "lint-rejected genome {} has a computable bound",
            genome.label()
        );
        checked += 1;
    }
    assert_eq!(
        checked,
        r.stats.lint_rejected,
        "every screen rejection must be re-checked"
    );
}

#[test]
fn front_is_bit_identical_with_screen_on_and_off() {
    // acceptance criterion: `--search evo` over an infeasible-seeded
    // corpus reports nonzero lint_rejected with the screen on, and the
    // final front is bit-identical to a screen-off run of the same seed —
    // across engine thread counts.
    let space = infeasible_seeded_space();
    let run = |threads: usize, lint: bool| -> EvoResult {
        let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
            .with_threads(threads);
        let cfg = EvoConfig {
            population: 12,
            generations: 3,
            max_evals: 60,
            seed: 21,
            lint,
            ..EvoConfig::default()
        };
        evolve(&engine, &space, &cfg).unwrap()
    };
    let signature = |r: &EvoResult| -> Vec<(String, usize, u64, String, u64, u64)> {
        r.records
            .iter()
            .map(|x| {
                (
                    x.quant_label(),
                    x.cores,
                    x.l2_kb,
                    x.sim.backend.clone(),
                    x.total_cycles,
                    x.energy_nj.to_bits(),
                )
            })
            .collect()
    };
    let screened = run(1, true);
    assert!(screened.stats.lint_rejected > 0, "{:?}", screened.stats);
    assert!(
        screened
            .pruned
            .iter()
            .any(|(_, why)| matches!(why, PruneReason::Lint(_))),
        "screen rejections must surface as PruneReason::Lint"
    );
    for (threads, lint) in [(1usize, false), (8, true), (8, false)] {
        let other = run(threads, lint);
        assert_eq!(
            signature(&screened),
            signature(&other),
            "archive differs (threads {threads}, lint {lint})"
        );
        assert_eq!(
            screened.front, other.front,
            "front differs (threads {threads}, lint {lint})"
        );
    }
    // the screen traded evaluation-path failures for static rejections,
    // never changing what got evaluated
    let unscreened = run(1, false);
    assert_eq!(unscreened.stats.lint_rejected, 0);
    assert_eq!(screened.evaluations, unscreened.evaluations);
}

#[test]
fn lint_report_json_is_byte_identical_across_runs_and_threads() {
    // acceptance criterion (determinism): the same model + configuration
    // renders byte-identical machine-readable reports across fresh
    // engines and across engine thread counts.
    let vector = Genome::uniform(8, BlockImpl::Im2col, 10, None).vector();
    let render = |threads: usize| -> String {
        let engine = EvalEngine::for_mobilenet(small(models::case2()), presets::gap8())
            .with_threads(threads);
        engine.lint(&vector).unwrap().to_json().to_string_pretty()
    };
    let a = render(1);
    let b = render(1);
    let c = render(8);
    assert_eq!(a, b, "report differs across runs");
    assert_eq!(a, c, "report differs across thread counts");
    assert!(a.contains("\"model\""), "{a}");
}
