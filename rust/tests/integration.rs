//! Cross-module integration tests: the full analysis pipeline on the
//! paper's workloads, asserting the *shapes* of the paper's findings
//! (§VIII-A/B/C) rather than absolute numbers.

use aladin::coordinator::{Analysis, Pipeline};
use aladin::dse::GridSearch;
use aladin::graph::qonnx;
use aladin::impl_aware::{decorate, layer_summaries, ImplConfig};
use aladin::models;
use aladin::platform::presets;
use aladin::util::json::Value;
use aladin::util::ToJson;

fn analyze(case: models::MobileNetConfig) -> Analysis {
    let (g, cfg) = case.build();
    Pipeline::new(presets::gap8(), cfg).analyze(g).unwrap()
}

fn analyses() -> Vec<Analysis> {
    models::all_cases().into_iter().map(analyze).collect()
}

#[test]
fn pipeline_runs_all_cases_full_width() {
    for a in analyses() {
        assert!(a.latency.total_cycles > 0, "{}", a.model);
        assert!(a.peak_l1 <= presets::gap8().l1_bytes);
        assert!(a.peak_l2 <= presets::gap8().l2_bytes);
        // 21 RC layers + RP + FC in the fused schedule
        let rc = a.sim.layers.iter().filter(|l| l.name.starts_with("RC")).count();
        assert_eq!(rc, 21, "{}", a.model);
        assert_eq!(
            a.sim.layers.iter().filter(|l| l.name.starts_with("FC")).count(),
            1
        );
    }
}

#[test]
fn fig5a_depthwise_reads_more_macs_than_pointwise() {
    // §VIII-A: with the Eq. 5 convention, Block10's depthwise conv is more
    // MAC-intensive than its standard (pointwise) conv …
    let a = analyze(models::case1());
    let get = |n: &str| a.impl_summary.iter().find(|r| r.name == n).unwrap().clone();
    let dw = get("Conv_dw10");
    let pw = get("Conv_pw10");
    assert!(dw.macs > pw.macs, "dw {} <= pw {}", dw.macs, pw.macs);
    // … while having a substantially lower memory footprint
    assert!(dw.param_mem_bits * 4 < pw.param_mem_bits);
    // and physically executing fewer MACs
    assert!(dw.macs_physical < pw.macs_physical);
}

#[test]
fn fig5b_lut_tail_inflates_case_parameter_memory() {
    let [a1, a2, _a3]: [Analysis; 3] = analyses().try_into().ok().unwrap();
    let lut_rows = |a: &Analysis| {
        a.impl_summary
            .iter()
            .filter(|r| r.impl_label == "lut")
            .count()
    };
    assert_eq!(lut_rows(&a1), 0);
    assert!(lut_rows(&a2) >= 6); // 3 blocks x (dw + pw)
    // per-layer: a LUT'd layer in case2 carries more parameter memory than
    // the same-precision im2col layer would (the table is extra)
    let dw9_lut = a2.impl_summary.iter().find(|r| r.name == "Conv_dw9").unwrap();
    assert_eq!(dw9_lut.impl_label, "lut");
    assert_eq!(dw9_lut.macs, 0); // MACs = 0 under LUT (paper §VI-A)
    assert!(dw9_lut.param_mem_bits > dw9_lut.macs_physical / 100); // non-trivial table
}

#[test]
fn fig5c_bops_scale_with_precision() {
    // Eq. 6: BOPs fall when Lw drops 8 -> 4 at equal structure
    let [a1, a2, _]: [Analysis; 3] = analyses().try_into().ok().unwrap();
    let bops = |a: &Analysis, n: &str| a.impl_summary.iter().find(|r| r.name == n).unwrap().bops;
    // Block 5 is int8-im2col in case1, int4-im2col in case2
    assert!(bops(&a2, "Conv_pw5") < bops(&a1, "Conv_pw5"));
}

#[test]
fn fig6a_int4_im2col_cycles_comparable_to_int8() {
    // §VIII-B: bit-unpacking makes 4-bit convolutions cost about the same
    // cycles as 8-bit ones in the early blocks
    let [a1, a2, _]: [Analysis; 3] = analyses().try_into().ok().unwrap();
    let cyc = |a: &Analysis, l: &str| {
        a.sim.layers.iter().find(|x| x.name == l).unwrap().cycles as f64
    };
    for layer in ["RC_2", "RC_3", "RC_4", "RC_5"] {
        let ratio = cyc(&a2, layer) / cyc(&a1, layer);
        assert!(
            (0.5..=1.6).contains(&ratio),
            "{layer}: int4/int8 cycle ratio {ratio}"
        );
    }
}

#[test]
fn fig6b_int4_reduces_memory_utilization() {
    let [a1, a2, _]: [Analysis; 3] = analyses().try_into().ok().unwrap();
    let l2 = |a: &Analysis, l: &str| {
        a.sim.layers.iter().find(|x| x.name == l).unwrap().l2_used_bytes
    };
    // deep pointwise layers: int4 weights halve the resident working set
    assert!(l2(&a2, "RC_19") < l2(&a1, "RC_19"));
}

#[test]
fn fig6a_2bit_lut_no_speedup_over_4bit() {
    // §VIII-B: the smaller 2-bit LUT contends more on the shared banks, so
    // the expected speed-up does not materialize
    let [_, a2, a3]: [Analysis; 3] = analyses().try_into().ok().unwrap();
    let cyc = |a: &Analysis, l: &str| {
        a.sim.layers.iter().find(|x| x.name == l).unwrap().cycles as f64
    };
    // Block 10 is 4-bit LUT in case2, 2-bit LUT in case3 (RC_21 = dw10)
    let ratio = cyc(&a3, "RC_21") / cyc(&a2, "RC_21");
    assert!(ratio > 0.85, "2-bit LUT unexpectedly fast: ratio {ratio}");
}

#[test]
fn lut_cases_slower_on_mac_optimized_cluster() {
    // §VIII-B: GAP8's cores are MAC-optimized, so LUT-based cases cost more
    // cycles than the all-im2col baseline
    let [a1, a2, a3]: [Analysis; 3] = analyses().try_into().ok().unwrap();
    assert!(a2.latency.total_cycles > a1.latency.total_cycles);
    assert!(a3.latency.total_cycles > a1.latency.total_cycles);
}

#[test]
fn fig7_grid_monotone_full_model() {
    let (g, cfg) = models::case2().build();
    let points = GridSearch::fig7(presets::gap8()).run_canonical(g, &cfg).unwrap();
    assert_eq!(points.len(), 9);
    for &l2 in &[256u64, 320, 512] {
        let mut row: Vec<_> = points.iter().filter(|p| p.l2_kb == l2).collect();
        row.sort_by_key(|p| p.cores);
        assert!(row[1].total_cycles <= row[0].total_cycles);
        assert!(row[2].total_cycles <= row[1].total_cycles);
    }
    // core-count saturation for the memory-bound deep layers: the 4->8
    // gain is smaller than the 2->4 gain (§VIII-C)
    let t = |c: usize| {
        points.iter().find(|p| p.cores == c && p.l2_kb == 256).unwrap().total_cycles as f64
    };
    assert!(t(2) / t(4) >= t(4) / t(8) * 0.99);
}

#[test]
fn qonnx_export_reanalyzes_identically() {
    let (g, cfg) = models::case3().build();
    let pipe = Pipeline::new(presets::gap8(), cfg);
    let direct = pipe.analyze(g.clone()).unwrap();

    let dir = std::env::temp_dir().join(format!("aladin-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("case3.qonnx.json");
    qonnx::export(&g).to_file(&path).unwrap();
    let via_file = pipe.analyze_file(&path).unwrap();
    assert_eq!(direct.latency.total_cycles, via_file.latency.total_cycles);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn analysis_json_serializes_and_parses() {
    let mut case = models::case1();
    case.width_mult = 0.25;
    let a = analyze(case);
    let text = a.to_json().to_string_pretty();
    let v = Value::parse(&text).unwrap();
    assert_eq!(v.str_field("model"), Some("case1"));
    assert!(v.get("sim").unwrap().u64_field("total_cycles").unwrap() > 0);
    assert!(!v.get("impl_summary").unwrap().as_arr().unwrap().is_empty());
}

#[test]
fn stm32n6_preset_analyzes() {
    let (g, cfg) = models::case1().build();
    let a = Pipeline::new(presets::stm32n6(), cfg).analyze(g).unwrap();
    assert!(a.latency.total_cycles > 0);
    assert!(a.peak_l1 <= presets::stm32n6().l1_bytes);
}

#[test]
fn listing1_yaml_config_drives_pipeline() {
    let yaml = r#"
Conv_dw10:
  implementation: LUT
Quant_pw10:
  implementation: thresholds
  filter_wise: True
"#;
    let cfg = ImplConfig::from_yaml(yaml).unwrap();
    let (g, _) = models::case1().build();
    let d = decorate(g, &cfg).unwrap();
    let rows = layer_summaries(&d);
    assert_eq!(
        rows.iter().find(|r| r.name == "Conv_dw10").unwrap().impl_label,
        "lut"
    );
    assert_eq!(
        rows.iter().find(|r| r.name == "Quant_pw10").unwrap().impl_label,
        "threshold-tree"
    );
}

#[test]
fn tighter_l1_still_schedules_or_fails_cleanly() {
    // the §VIII-C note: "significantly reducing [L1] capacity results in
    // schedulability failures, as expected"
    let (g, cfg) = models::case1().build();
    let mut small = presets::gap8();
    small.l1_bytes = 16 * 1024;
    let r = Pipeline::new(small, cfg.clone()).analyze(g.clone());
    // 16 kB still schedules (tiled harder) …
    let a = r.unwrap();
    assert!(a.peak_l1 <= 16 * 1024);

    let mut tiny = presets::gap8();
    tiny.l1_bytes = 1024; // … 1 kB cannot hold the LUT-free working set
    tiny.l1_banks = 4;
    tiny.l2_bytes = 512 * 1024;
    let r = Pipeline::new(tiny, cfg).analyze(g);
    assert!(matches!(r, Err(aladin::AladinError::Infeasible { .. })));
}
