//! Property and edge-case tests for the batched im2col/GEMM executor:
//! bit-identity of the batched path against the scalar reference on random
//! graphs, shapes, bit-widths and thread counts; pinned kernels for grouped
//! convolution, asymmetric padding, stride > 1 and avg-pool shift rounding;
//! scratch-arena reuse; and thread-count invariance of the DSE accuracy
//! stage.

use aladin::dse::{DesignVector, EvalEngine};
use aladin::exec::{
    measure, measure_batched, measure_scalar, EvalVectors, Executable, Scratch, TensorI,
};
use aladin::graph::builder::GraphBuilder;
use aladin::graph::ir::{ConvAttrs, Graph, PoolAttrs};
use aladin::graph::tensor::{ElemType, TensorSpec};
use aladin::impl_aware::{decorate, ImplConfig};
use aladin::models::{self, BlockConfig, BlockImpl};
use aladin::platform::presets;
use aladin::util::prng::check_property;
use std::sync::Arc;

fn dec(g: Graph) -> Arc<Graph> {
    Arc::new(decorate(g, &ImplConfig::default()).unwrap())
}

fn scalar_outputs(exe: &Executable, vectors: &EvalVectors) -> Vec<TensorI> {
    let mut scratch = Scratch::new();
    vectors
        .inputs
        .iter()
        .map(|v| exe.run_int_in(v, &mut scratch).unwrap())
        .collect()
}

/// Assert the batched path reproduces the scalar reference bit-for-bit at
/// every requested thread count — per-vector output tensors (shape and
/// data) and the full measured-accuracy record (fingerprint, matches).
/// Returns the scalar record's accuracy (integer-vs-float top-1 agreement)
/// so callers can additionally assert a fidelity floor.
fn assert_paths_agree(g: &Arc<Graph>, vectors: &EvalVectors, threads: &[usize]) -> f64 {
    let exe = Executable::lower(g.clone(), vectors).unwrap();
    let scalar = scalar_outputs(&exe, vectors);
    let rs = measure_scalar(g.clone(), vectors).unwrap();
    for &t in threads {
        let batched = exe.run_int_batched_outputs(&vectors.inputs, t).unwrap();
        assert_eq!(scalar, batched, "per-vector outputs diverged at {t} threads");
        let rb = measure_batched(g.clone(), vectors, t).unwrap();
        assert_eq!(
            rs.output_fingerprint, rb.output_fingerprint,
            "record fingerprint diverged at {t} threads"
        );
        assert_eq!(rs.matches, rb.matches, "top-1 matches diverged at {t} threads");
        assert_eq!(rs.n, rb.n);
    }
    rs.accuracy
}

/// A small conv net around one convolution of interest: conv -> relu ->
/// per-tensor int8 requant -> optional pool -> flatten -> 5-way classifier.
fn conv_edge_net(conv: ConvAttrs, pool: Option<(PoolAttrs, bool)>) -> Arc<Graph> {
    let w = ElemType::int(8);
    let mut b = GraphBuilder::new(
        "edge_net",
        TensorSpec::chw(4, 8, 8, ElemType::int(8)),
        ElemType::int(32),
    );
    b.conv("c0", conv, w).relu("r0").quant("q0", ElemType::int(8), false);
    if let Some((attrs, avg)) = pool {
        if avg {
            b.avg_pool("ap", attrs);
        } else {
            b.max_pool("mp", attrs);
        }
    }
    b.flatten("fl").gemm("fc", 5, w).quant("q_out", ElemType::int(8), false);
    dec(b.finish())
}

/// Property: on random sequential conv nets (random input shape, kernel /
/// stride / padding geometry, optional grouped second conv, optional pool,
/// 4- or 8-bit weights, per-tensor or per-channel requant) the batched
/// executor is bit-identical to the scalar reference at a random thread
/// count, and the measured-accuracy records carry the same fingerprint.
#[test]
fn prop_batched_bit_identical_on_random_nets() {
    check_property("batched_vs_scalar", 6, |rng| {
        let bits = *rng.choice(&[4u8, 8]);
        let wt = ElemType::int(bits);
        let cin = rng.range(2, 4);
        let h = rng.range(7, 12);
        let w = rng.range(7, 12);
        let mut b = GraphBuilder::new(
            "prop_net",
            TensorSpec::chw(cin, h, w, ElemType::int(8)),
            ElemType::int(32),
        );
        let c0 = ConvAttrs {
            out_channels: 4,
            kernel: (rng.range(1, 3), rng.range(1, 3)),
            stride: (rng.range(1, 2), rng.range(1, 2)),
            padding: (rng.range(0, 1), rng.range(0, 1)),
            groups: 1,
        };
        b.conv("c0", c0, wt).relu("r0").quant("q0", wt, rng.chance(0.5));
        if rng.chance(0.6) {
            let c1 = ConvAttrs {
                out_channels: 4,
                kernel: (rng.range(1, 2), rng.range(1, 2)),
                stride: (1, 1),
                padding: (rng.range(0, 1), rng.range(0, 1)),
                groups: *rng.choice(&[1usize, 2, 4]),
            };
            b.conv("c1", c1, wt).relu("r1").quant("q1", wt, rng.chance(0.5));
        }
        // flatten needs a per-tensor scale, so requant to plain int8 first
        b.quant("q_flat", ElemType::int(8), false);
        let dims = b.cur_spec().dims.clone();
        if dims[1] >= 2 && dims[2] >= 2 && rng.chance(0.5) {
            if rng.chance(0.5) {
                b.max_pool("mp", PoolAttrs::square(2, 2));
            } else {
                b.avg_pool("ap", PoolAttrs::square(2, 2));
            }
        }
        b.flatten("fl").gemm("fc", rng.range(3, 7), wt).quant("q_out", ElemType::int(8), false);
        let g = dec(b.finish());
        let vectors = EvalVectors::synthetic(rng.next_u64(), vec![cin, h, w], rng.range(2, 6));
        let threads = rng.range(1, 4);
        assert_paths_agree(&g, &vectors, &[threads]);
    });
}

#[test]
fn grouped_and_depthwise_conv_bit_identical_and_faithful() {
    let vectors = EvalVectors::synthetic(21, vec![4, 8, 8], 8);
    let grouped = ConvAttrs {
        out_channels: 6,
        kernel: (3, 3),
        stride: (1, 1),
        padding: (1, 1),
        groups: 2,
    };
    let acc = assert_paths_agree(&conv_edge_net(grouped, None), &vectors, &[1, 3]);
    assert!(acc >= 0.5, "grouped-conv int8 fidelity {acc} below floor");
    let dw = ConvAttrs::depthwise(4, 3, 1, 1);
    let acc = assert_paths_agree(&conv_edge_net(dw, None), &vectors, &[1, 3]);
    assert!(acc >= 0.5, "depthwise-conv int8 fidelity {acc} below floor");
}

#[test]
fn asymmetric_padding_bit_identical_and_faithful() {
    let vectors = EvalVectors::synthetic(22, vec![4, 8, 8], 8);
    for padding in [(2, 0), (0, 1)] {
        let conv = ConvAttrs {
            out_channels: 5,
            kernel: (3, 3),
            stride: (1, 1),
            padding,
            groups: 1,
        };
        let acc = assert_paths_agree(&conv_edge_net(conv, None), &vectors, &[1, 3]);
        assert!(acc >= 0.5, "padding {padding:?} int8 fidelity {acc} below floor");
    }
}

#[test]
fn strided_conv_bit_identical_and_faithful() {
    let vectors = EvalVectors::synthetic(23, vec![4, 8, 8], 8);
    for stride in [(2, 2), (2, 1)] {
        let conv = ConvAttrs {
            out_channels: 4,
            kernel: (3, 3),
            stride,
            padding: (1, 1),
            groups: 1,
        };
        let acc = assert_paths_agree(&conv_edge_net(conv, None), &vectors, &[1, 3]);
        assert!(acc >= 0.5, "stride {stride:?} int8 fidelity {acc} below floor");
    }
}

#[test]
fn padded_pools_bit_identical_and_faithful() {
    let vectors = EvalVectors::synthetic(24, vec![4, 8, 8], 8);
    let conv = ConvAttrs::standard(4, 3, 1, 1);
    let attrs = PoolAttrs {
        kernel: (3, 3),
        stride: (2, 2),
        padding: (1, 0),
    };
    for avg in [true, false] {
        let g = conv_edge_net(conv.clone(), Some((attrs.clone(), avg)));
        let acc = assert_paths_agree(&g, &vectors, &[1, 3]);
        assert!(acc >= 0.5, "padded pool (avg={avg}) int8 fidelity {acc} below floor");
    }
}

/// Pinned avg-pool rounding: the shift-style division rounds ties away
/// from zero in both directions, identically on both paths. The input is
/// constructed so the 4-tap window sums to 130 -> 130/4 = 32.5 -> 33 (and
/// the negated vector to -33).
#[test]
fn avg_pool_shift_rounding_ties_away_pinned() {
    let mut b = GraphBuilder::new(
        "avg_tie",
        TensorSpec::chw(1, 2, 2, ElemType::int(8)),
        ElemType::int(32),
    );
    b.avg_pool("ap", PoolAttrs::square(2, 2));
    let g = dec(b.finish());
    let v0 = vec![1.0, 4.0 / 127.0, -2.0 / 127.0, 1.0 / 127.0];
    let v1: Vec<f64> = v0.iter().map(|x| -x).collect();
    let vectors = EvalVectors {
        dims: vec![1, 2, 2],
        inputs: vec![v0, v1],
        seed: 0,
    };
    let exe = Executable::lower(g, &vectors).unwrap();
    let q: Vec<i64> =
        vectors.inputs[0].iter().map(|&r| exe.input_quant().quantize(r)).collect();
    assert_eq!(q, vec![127, 4, -2, 1], "input quantization drifted; tie setup invalid");
    let out0 = exe.run_int(&vectors.inputs[0]).unwrap();
    assert_eq!(out0.dims, vec![1, 1, 1]);
    assert_eq!(out0.data, vec![33], "tie 32.5 must round away from zero");
    let out1 = exe.run_int(&vectors.inputs[1]).unwrap();
    assert_eq!(out1.data, vec![-33], "tie -32.5 must round away from zero");
    let batched = exe.run_int_batched_outputs(&vectors.inputs, 2).unwrap();
    assert_eq!(batched, vec![out0, out1]);
}

/// The caller-provided scratch arena changes allocation behavior only:
/// outputs through a reused arena are bit-identical to fresh-allocation
/// runs, and the arena actually pools buffers between vectors.
#[test]
fn scratch_arena_reuse_is_bit_identical() {
    let (g, cfg) = models::lenet(8, (3, 32, 32), 10);
    let g = Arc::new(decorate(g, &cfg).unwrap());
    let vectors = models::lenet_vectors(4);
    let exe = Executable::lower(g, &vectors).unwrap();
    let mut scratch = Scratch::new();
    for v in &vectors.inputs {
        let fresh = exe.run_int(v).unwrap();
        let pooled = exe.run_int_in(v, &mut scratch).unwrap();
        assert_eq!(fresh, pooled, "arena reuse changed the output");
    }
    assert!(scratch.pooled() > 0, "arena never recycled a buffer");
}

#[test]
fn measure_parity_across_bit_widths_and_threads() {
    let vectors = models::lenet_vectors(6);
    for bits in [8u8, 4, 2] {
        let (g, cfg) = models::lenet(bits, (3, 32, 32), 10);
        let g = Arc::new(decorate(g, &cfg).unwrap());
        let rs = measure_scalar(g.clone(), &vectors).unwrap();
        for t in [1usize, 4] {
            let rb = measure_batched(g.clone(), &vectors, t).unwrap();
            assert_eq!(
                rs.output_fingerprint, rb.output_fingerprint,
                "bits={bits} threads={t}"
            );
            assert_eq!(rs.matches, rb.matches, "bits={bits} threads={t}");
        }
        // the default entry point is the single-threaded batched path
        let rm = measure(g, &vectors).unwrap();
        assert_eq!(rs.output_fingerprint, rm.output_fingerprint, "bits={bits}");
    }
}

/// The LUT implementation (materialized multiplication tables, LUT
/// requant) goes through the same batched kernels: a MobileNet with every
/// block on the LUT path agrees with the scalar reference.
#[test]
fn mobilenet_lut_blocks_bit_identical() {
    let mut case = models::case2();
    case.width_mult = 0.25;
    case.pilot = BlockConfig::new(4, BlockImpl::Lut);
    case.classifier = BlockConfig::new(4, BlockImpl::Lut);
    for b in case.blocks.iter_mut() {
        *b = BlockConfig::new(4, BlockImpl::Lut);
    }
    let (g, cfg) = case.build();
    let g = Arc::new(decorate(g, &cfg).unwrap());
    let vectors = models::cifar_vectors(2);
    let rs = measure_scalar(g.clone(), &vectors).unwrap();
    let rb = measure_batched(g, &vectors, 4).unwrap();
    assert_eq!(rs.output_fingerprint, rb.output_fingerprint);
    assert_eq!(rs.matches, rb.matches);
}

/// The DSE accuracy stage runs on the batched path; its record must not
/// depend on the engine's worker-thread count (the cache key is
/// (quant axis, vector set) — thread count never enters it).
#[test]
fn engine_accuracy_invariant_across_thread_counts() {
    let mut case = models::case2();
    case.width_mult = 0.25;
    let vectors = Arc::new(models::cifar_vectors(2));
    let mut records = Vec::new();
    for threads in [1usize, 3] {
        let engine = EvalEngine::for_mobilenet(case.clone(), presets::gap8())
            .with_measured_accuracy(vectors.clone())
            .with_threads(threads);
        let r = engine.evaluate(&DesignVector::of_hw(4, 320)).unwrap();
        records.push((r.accuracy.unwrap().to_bits(), r.accuracy_fingerprint.unwrap()));
    }
    assert_eq!(records[0], records[1], "accuracy record depends on engine thread count");
}
