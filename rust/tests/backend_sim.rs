//! Backend regression tests (tentpole acceptance): the extracted
//! `ScratchpadCluster` backend is pinned bit-identical to the
//! pre-refactor bounded-buffer simulator — re-derived here, independently,
//! against the public schedule/cost API — and every backend upholds the
//! shared cross-layer contracts (exact exposed-cycle decomposition,
//! single-channel prefetch window, per-layer core + coupling == monolithic
//! simulation).

use aladin::graph::builder::GraphBuilder;
use aladin::graph::ir::ConvAttrs;
use aladin::graph::tensor::{ElemType, TensorSpec};
use aladin::impl_aware::{decorate, ImplConfig, NodeImplSpec};
use aladin::platform::{presets, PlatformSpec};
use aladin::platform_aware::{build_schedule, fuse, LayerSchedule, NetworkSchedule};
use aladin::sim::{
    couple_layer, simulate, simulate_layer_pipeline, simulate_traced, tile_compute_cycles,
    BackendKind,
};
use aladin::util::prng::{check_property, Prng};
use std::sync::Arc;

/// Random small conv net (one or two fused layers, random precisions and
/// conv implementations) — the corpus the pinned comparison runs over.
fn random_decorated(rng: &mut Prng) -> aladin::graph::ir::Graph {
    let cin = rng.range(1, 16);
    let hw = [4, 8, 16, 32][rng.range(0, 3)];
    let cout = rng.range(1, 64);
    let bits = [2u8, 4, 8][rng.range(0, 2)];
    let k = [1usize, 3][rng.range(0, 1)];
    let two_layers = rng.chance(0.5);

    let mut b = GraphBuilder::new(
        "rand",
        TensorSpec::chw(cin, hw, hw, ElemType::int(8)),
        ElemType::int(if bits < 8 { 16 } else { 32 }),
    );
    b.conv(
        "c0",
        ConvAttrs::standard(cout, k, 1, if k == 3 { 1 } else { 0 }),
        ElemType::int(bits),
    )
    .relu("r0")
    .quant("q0", ElemType::int(bits), rng.chance(0.5));
    if two_layers {
        b.conv("c1", ConvAttrs::standard(rng.range(1, 128), 1, 1, 0), ElemType::int(bits))
            .relu("r1")
            .quant("q1", ElemType::int(bits), false);
    }
    let g = b.finish();

    let mut cfg = ImplConfig::default();
    let impls = ["im2col", "lut", "direct"];
    cfg.set_node(
        "c0",
        NodeImplSpec {
            implementation: Some(impls[rng.range(0, 2)].into()),
            ..Default::default()
        },
    );
    decorate(g, &cfg).unwrap()
}

/// A fixed two-conv chain whose second layer carries a real weight set —
/// exercises the prefetch coupling deterministically.
fn chain_schedule(platform: &PlatformSpec) -> NetworkSchedule {
    let mut b = GraphBuilder::new(
        "t",
        TensorSpec::chw(32, 16, 16, ElemType::int(8)),
        ElemType::int(32),
    );
    b.conv("c0", ConvAttrs::standard(128, 3, 1, 1), ElemType::int(8))
        .relu("r0")
        .quant("q0", ElemType::int(8), false)
        .conv("c1", ConvAttrs::standard(256, 3, 1, 1), ElemType::int(8))
        .relu("r1")
        .quant("q1", ElemType::int(8), false);
    let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
    build_schedule(&fuse(&g).unwrap(), &Arc::new(platform.clone())).unwrap()
}

/// Per-layer numbers of the pre-refactor simulator.
struct RefLayer {
    cycles: u64,
    compute_cycles: u64,
    dma_l1_cycles: u64,
    dma_l3_cycles: u64,
    exposed_dma_l1_cycles: u64,
    exposed_dma_l3_cycles: u64,
    hidden_dma_l3_cycles: u64,
}

/// The pre-refactor within-layer tile pipeline: two-slot double buffering
/// in the Dory channel order (in[0], in[1], out[0], in[2], out[1], …), or
/// the fully serialized single-buffer loop. Returns
/// `(pipeline_cycles, compute_busy, dma_l1_busy)`.
fn ref_pipeline(ls: &LayerSchedule, p: &PlatformSpec) -> (u64, u64, u64) {
    let plan = &ls.tile;
    let n = plan.n_tiles();
    let dma = &p.dma_l2_l1;
    let temp_load = dma.cycles(plan.temp_bytes);
    let dma_in_one = dma.cycles(plan.tile_in_dma_bytes());
    let dma_out_one = dma.cycles(plan.tile_output_bytes);
    let compute_one = tile_compute_cycles(&ls.layer, plan, p).total();

    let mut dma_free = temp_load;
    let mut compute_free = 0u64;
    let mut compute_busy = 0u64;
    let mut in_ready = vec![0u64; n];
    let mut compute_done = vec![0u64; n];
    let mut out_done = vec![0u64; n];
    if plan.double_buffered {
        for i in 0..n.min(2) {
            in_ready[i] = dma_free + dma_in_one;
            dma_free = in_ready[i];
        }
        for i in 0..n {
            let out_slot_free = if i >= 2 { out_done[i - 2] } else { 0 };
            let cstart = in_ready[i].max(compute_free).max(out_slot_free);
            compute_done[i] = cstart + compute_one;
            compute_free = compute_done[i];
            compute_busy += compute_one;
            let wstart = compute_done[i].max(dma_free);
            out_done[i] = wstart + dma_out_one;
            dma_free = out_done[i];
            if i + 2 < n {
                let in_start = dma_free.max(compute_done[i]);
                in_ready[i + 2] = in_start + dma_in_one;
                dma_free = in_ready[i + 2];
            }
        }
    } else {
        for i in 0..n {
            let prev_done = if i == 0 { 0 } else { out_done[i - 1] };
            let in_start = dma_free.max(prev_done);
            in_ready[i] = in_start + dma_in_one;
            dma_free = in_ready[i];
            let cstart = in_ready[i].max(compute_free);
            compute_done[i] = cstart + compute_one;
            compute_free = compute_done[i];
            compute_busy += compute_one;
            let wstart = compute_done[i].max(dma_free);
            out_done[i] = wstart + dma_out_one;
            dma_free = out_done[i];
        }
    }
    let pipeline_end = out_done.last().copied().unwrap_or(dma_free);
    let dma_l1 = temp_load + (dma_in_one + dma_out_one) * n as u64;
    (pipeline_end, compute_busy, dma_l1)
}

/// The pre-refactor cross-layer composition: the first layer's weights
/// prefetch during model load; every later layer hides its L3 traffic only
/// inside the predecessor's micro-DMA-free window.
fn reference_scratchpad(s: &NetworkSchedule) -> Vec<RefLayer> {
    let mut hide_window = u64::MAX;
    let mut out = Vec::new();
    for ls in &s.layers {
        let (pipeline, compute, dma_l1) = ref_pipeline(ls, &s.platform);
        let dma_l3 = s.platform.dma_l3_l2.cycles(ls.l2.l3_bytes());
        let (hidden, exposed_l3) = if ls.l2.prefetchable {
            let h = dma_l3.min(hide_window);
            (h, dma_l3 - h)
        } else {
            (0, dma_l3)
        };
        let cycles = exposed_l3 + pipeline;
        hide_window = pipeline;
        out.push(RefLayer {
            cycles,
            compute_cycles: compute,
            dma_l1_cycles: dma_l1,
            dma_l3_cycles: dma_l3,
            exposed_dma_l1_cycles: pipeline - compute,
            exposed_dma_l3_cycles: exposed_l3,
            hidden_dma_l3_cycles: hidden,
        });
    }
    out
}

#[test]
fn scratchpad_backend_pinned_bit_identical_to_reference() {
    // acceptance criterion: extracting the scratchpad model behind the
    // Backend trait moved no cycle anywhere, on a random corpus of nets
    // and platform knob settings
    check_property("scratchpad_pinned", 80, |rng| {
        let g = random_decorated(rng);
        let layers = fuse(&g).unwrap();
        let cores = [1usize, 2, 4, 8][rng.range(0, 3)];
        let l2_kb = [128u64, 256, 320, 512][rng.range(0, 3)];
        let p = presets::gap8_with(cores, l2_kb);
        assert_eq!(p.backend, BackendKind::ScratchpadCluster);
        let s = match build_schedule(&layers, &Arc::new(p)) {
            Ok(s) => s,
            Err(aladin::AladinError::Infeasible { .. }) => return,
            Err(e) => panic!("unexpected error: {e}"),
        };
        let got = simulate(&s);
        assert_eq!(got.backend, "scratchpad");
        let want = reference_scratchpad(&s);
        assert_eq!(got.layers.len(), want.len());
        for (a, b) in got.layers.iter().zip(&want) {
            assert_eq!(a.cycles, b.cycles, "{}", a.name);
            assert_eq!(a.compute_cycles, b.compute_cycles, "{}", a.name);
            assert_eq!(a.dma_l1_cycles, b.dma_l1_cycles, "{}", a.name);
            assert_eq!(a.dma_l3_cycles, b.dma_l3_cycles, "{}", a.name);
            assert_eq!(a.exposed_dma_l1_cycles, b.exposed_dma_l1_cycles, "{}", a.name);
            assert_eq!(a.exposed_dma_l3_cycles, b.exposed_dma_l3_cycles, "{}", a.name);
            assert_eq!(a.hidden_dma_l3_cycles, b.hidden_dma_l3_cycles, "{}", a.name);
            assert_eq!(
                a.stall_cycles,
                b.exposed_dma_l1_cycles + b.exposed_dma_l3_cycles,
                "{}",
                a.name
            );
        }
    });
}

#[test]
fn every_backend_upholds_the_exposed_cycle_identity() {
    // the cross-layer contract is backend-independent: exact decomposition
    // per layer, prefetch hiding bounded by the predecessor's window, and
    // traced == untraced totals with a timeline covering the whole run
    check_property("backend_identity", 60, |rng| {
        let g = random_decorated(rng);
        let layers = fuse(&g).unwrap();
        let cores = [2usize, 4, 8][rng.range(0, 2)];
        let l2_kb = [128u64, 256, 512][rng.range(0, 2)];
        for kind in BackendKind::all() {
            let mut p = presets::gap8_with(cores, l2_kb);
            p.backend = kind;
            let s = match build_schedule(&layers, &Arc::new(p)) {
                Ok(s) => s,
                Err(aladin::AladinError::Infeasible { .. }) => continue,
                Err(e) => panic!("unexpected error: {e}"),
            };
            let r = simulate(&s);
            assert_eq!(r.backend, kind.label());
            for l in &r.layers {
                assert!(l.cycles >= l.compute_cycles, "{}: {}", kind.label(), l.name);
                assert_eq!(
                    l.compute_cycles + l.exposed_dma_l1_cycles + l.exposed_dma_l3_cycles,
                    l.cycles,
                    "{}: {}",
                    kind.label(),
                    l.name
                );
                assert_eq!(
                    l.exposed_dma_l3_cycles + l.hidden_dma_l3_cycles,
                    l.dma_l3_cycles,
                    "{}: {}",
                    kind.label(),
                    l.name
                );
                assert_eq!(l.stall_cycles, l.exposed_dma_l1_cycles + l.exposed_dma_l3_cycles);
            }
            for w in r.layers.windows(2) {
                assert!(
                    w[1].hidden_dma_l3_cycles <= w[0].cycles - w[0].exposed_dma_l3_cycles,
                    "{}: {} overbooked the micro-DMA channel",
                    kind.label(),
                    w[1].name
                );
            }
            let (tr, tl) = simulate_traced(&s);
            assert_eq!(tr.total_cycles(), r.total_cycles(), "{}", kind.label());
            assert_eq!(tl.end(), r.total_cycles(), "{}", kind.label());
        }
    });
}

#[test]
fn per_layer_core_composes_identically_across_backends() {
    // the layer-grained cache contract holds for every backend: the
    // coupling-free per-layer core + couple_layer reproduces the
    // monolithic simulation bitwise, and the backend's analytic bound
    // never exceeds its own pipeline
    for kind in BackendKind::all() {
        let mut p = presets::gap8_with(8, 320);
        p.backend = kind;
        let s = chain_schedule(&p);
        let whole = simulate(&s);
        let mut hide = u64::MAX;
        for (ls, expect) in s.layers.iter().zip(&whole.layers) {
            let pipe = simulate_layer_pipeline(ls, &s.platform);
            assert!(
                pipe.lb_cycles <= pipe.pipeline_cycles,
                "{}: lb {} > pipeline {}",
                kind.label(),
                pipe.lb_cycles,
                pipe.pipeline_cycles
            );
            let got = couple_layer(&pipe, ls.l2.prefetchable, hide);
            hide = pipe.pipeline_cycles;
            assert_eq!(got.cycles, expect.cycles, "{}: {}", kind.label(), expect.name);
            assert_eq!(got.compute_cycles, expect.compute_cycles);
            assert_eq!(got.exposed_dma_l1_cycles, expect.exposed_dma_l1_cycles);
            assert_eq!(got.exposed_dma_l3_cycles, expect.exposed_dma_l3_cycles);
            assert_eq!(got.hidden_dma_l3_cycles, expect.hidden_dma_l3_cycles);
        }
    }
}

#[test]
fn backend_energy_totals_are_positive_and_distinct_models_are_wired() {
    // the energy model runs off the fused layers alone; each backend
    // produces a positive total, sharded charges its merge term on top of
    // the scratchpad cost, and the systolic trade-off is finite
    let mut b = GraphBuilder::new(
        "e",
        TensorSpec::chw(16, 16, 16, ElemType::int(8)),
        ElemType::int(32),
    );
    b.conv("c0", ConvAttrs::standard(64, 3, 1, 1), ElemType::int(8))
        .relu("r0")
        .quant("q0", ElemType::int(8), false);
    let g = decorate(b.finish(), &ImplConfig::default()).unwrap();
    let fused = fuse(&g).unwrap();
    let mut by_kind = Vec::new();
    for kind in BackendKind::all() {
        let mut p = presets::gap8();
        p.backend = kind;
        let e = aladin::sim::model_energy_nj(&fused, &p);
        assert!(e.is_finite() && e > 0.0, "{}: {e}", kind.label());
        by_kind.push((kind, e));
    }
    let scratch = by_kind[0].1;
    let sharded = by_kind[1].1;
    assert!(sharded > scratch, "merge term missing: {sharded} <= {scratch}");
}
