//! Golden and property tests for the bit-exact integer interpreter:
//! top-1 fidelity of the integer execution against the float reference on
//! the bundled LeNet vectors, bit-identical repeated runs (per-layer), and
//! hardware-axis invariance of the measured-accuracy stage.

use aladin::dse::{DesignVector, EvalEngine};
use aladin::exec::{measure, EvalVectors, Executable};
use aladin::graph::ir::Graph;
use aladin::impl_aware::decorate;
use aladin::models;
use aladin::platform::presets;
use aladin::util::prng::check_property;
use std::sync::Arc;

fn lenet_decorated(bits: u8) -> Arc<Graph> {
    let (g, cfg) = models::lenet(bits, (3, 32, 32), 10);
    Arc::new(decorate(g, &cfg).unwrap())
}

/// Golden test: int8 LeNet through the deployed arithmetic must agree with
/// the float reference on top-1 for at least 60% of the bundled vectors.
///
/// Documented tolerance: symmetric int8 weights + calibrated activation
/// ranges keep per-layer relative quantization noise around 1%, so
/// empirical top-1 agreement sits near 0.9–1.0 on the random teacher; the
/// 0.60 floor only absorbs the teacher's near-tied logits (10 random
/// logits leave a few percent of vectors within quantization noise of a
/// class flip). int2 execution (weights collapsing to {-1, 0, 1}) must not
/// beat int8 on the *same* teacher (the parameter seeds exclude bit-width
/// on purpose).
#[test]
fn lenet_int8_top1_matches_float_reference_within_tolerance() {
    let vectors = models::lenet_vectors(32);
    let r8 = measure(lenet_decorated(8), &vectors).unwrap();
    assert_eq!(r8.n, 32);
    assert!(
        r8.accuracy >= 0.60,
        "int8 fidelity {} below documented tolerance 0.60",
        r8.accuracy
    );

    let r2 = measure(lenet_decorated(2), &vectors).unwrap();
    assert!(
        r2.accuracy <= r8.accuracy,
        "int2 fidelity {} beats int8 {} on the same teacher",
        r2.accuracy,
        r8.accuracy
    );
}

/// Property: per-layer integer outputs are bit-identical across repeated
/// lowerings and runs (the interpreter has no hidden state, no ambient
/// randomness, no platform dependence).
#[test]
fn prop_per_layer_outputs_bit_identical_across_runs() {
    let decorated = lenet_decorated(4);
    check_property("exec_bit_identical", 4, |rng| {
        let n = rng.range(1, 2);
        let vectors = EvalVectors::synthetic(rng.next_u64(), vec![3, 32, 32], n);
        let a = Executable::lower(decorated.clone(), &vectors).unwrap();
        let b = Executable::lower(decorated.clone(), &vectors).unwrap();
        for input in &vectors.inputs {
            let ea = a.run_int_edges(input).unwrap();
            let eb = b.run_int_edges(input).unwrap();
            assert_eq!(ea, eb, "per-layer outputs diverged between runs");
            // and a second run of the same executable is bit-identical too
            assert_eq!(ea, a.run_int_edges(input).unwrap());
        }
    });
}

/// Property: the measured-accuracy record is invariant across
/// hardware-axis changes — any (cores, L2) point reports the same
/// accuracy bits and output fingerprint, served from one cached
/// interpreter evaluation.
#[test]
fn prop_measured_accuracy_invariant_across_hardware_axis() {
    let mut case = models::case2();
    case.width_mult = 0.25;
    let engine = EvalEngine::for_mobilenet(case, presets::gap8())
        .with_measured_accuracy(Arc::new(models::cifar_vectors(2)));
    let base = engine.evaluate(&DesignVector::of_hw(4, 320)).unwrap();
    let base_acc = base.accuracy.unwrap();
    let base_fp = base.accuracy_fingerprint.unwrap();
    check_property("acc_hw_invariant", 4, |rng| {
        let cores = *rng.choice(&[2usize, 4, 8]);
        let l2 = *rng.choice(&[256u64, 320, 512]);
        let r = engine.evaluate(&DesignVector::of_hw(cores, l2)).unwrap();
        assert_eq!(
            r.accuracy.unwrap().to_bits(),
            base_acc.to_bits(),
            "accuracy changed at cores={cores} l2={l2}"
        );
        assert_eq!(r.accuracy_fingerprint.unwrap(), base_fp);
    });
    assert_eq!(
        engine.stats().acc_computed,
        1,
        "hardware sweep must reuse the single cached interpreter eval"
    );
}

/// The float reference is self-consistent: its output argmax reproduces
/// the calibration labels, and the integer path's output shape matches.
#[test]
fn float_reference_labels_consistent_with_outputs() {
    let decorated = lenet_decorated(8);
    let vectors = models::lenet_vectors(4);
    let exe = Executable::lower(decorated, &vectors).unwrap();
    for (i, input) in vectors.inputs.iter().enumerate() {
        let f = exe.run_float(input).unwrap();
        assert_eq!(f.argmax(), exe.calibration().ref_top1[i]);
        let q = exe.run_int(input).unwrap();
        assert_eq!(q.dims, f.dims);
        assert_eq!(q.dims, vec![10]);
    }
}
