//! Bench: Fig. 5 — implementation-aware analysis of Cases 1-3.
//!
//! Regenerates the layer-wise MACs / memory / BOPs series of paper Fig. 5
//! and times the decoration pass (the platform-independent half of the
//! pipeline).

use aladin::impl_aware::{decorate, layer_summaries};
use aladin::models;
use aladin::util::bench::{bench, black_box};

fn main() {
    println!("=== Fig. 5: implementation-aware analysis ===");

    for case in models::all_cases() {
        let name = case.name.clone();
        let (g, cfg) = case.build();
        let decorated = decorate(g.clone(), &cfg).expect("decoration failed");
        let rows = layer_summaries(&decorated);

        // the figure's three series
        println!("\n-- {name} --");
        println!(
            "{:<18} {:>14} {:>12} {:>16}",
            "layer", "MACs(eq5)", "mem kB", "BOPs"
        );
        for r in &rows {
            if r.op == "Relu" || r.op == "Flatten" {
                continue;
            }
            println!(
                "{:<18} {:>14} {:>12.1} {:>16}",
                r.name,
                r.macs,
                r.total_mem_kb(),
                r.bops
            );
        }
        println!(
            "totals: MACs(eq5) {}  physical MACs {}  BOPs {}  params {:.1} kB",
            decorated.total_macs(),
            rows.iter().map(|r| r.macs_physical).sum::<u64>(),
            decorated.total_bops(),
            decorated.total_param_bits() as f64 / 8192.0
        );

        bench(&format!("fig5/decorate/{name}"), 3, 20, || {
            let (g, cfg) = {
                // rebuild to include graph construction in a fair end-to-end
                // measurement of the user-facing operation
                black_box(())
                ;
                (g.clone(), cfg.clone())
            };
            decorate(g, &cfg).unwrap()
        });
    }
}
