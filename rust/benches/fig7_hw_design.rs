//! Bench: Fig. 7 — hardware design-space exploration grid (Case 2).
//!
//! Regenerates the 3x3 cores x L2 grid of paper Fig. 7 (total cycles per
//! point + tiling configurations) and times the full grid search — the
//! operation whose cost determines how much of the design space a user can
//! screen interactively.

use aladin::dse::{speedups, GridSearch};
use aladin::models;
use aladin::platform::presets;
use aladin::util::bench::bench;

fn main() {
    println!("=== Fig. 7: HW design-space exploration (Case 2) ===");

    let (g, cfg) = models::case2().build();
    let grid = GridSearch::fig7(presets::gap8());
    let points = grid.run_canonical(g.clone(), &cfg).unwrap();

    println!(
        "{:>5} {:>7} {:>14} {:>9} {:>12}",
        "cores", "L2 kB", "cycles", "speedup", "L3 traf kB"
    );
    for (p, (_, _, s)) in points.iter().zip(speedups(&points)) {
        println!(
            "{:>5} {:>7} {:>14} {:>8.2}x {:>12.1}",
            p.cores, p.l2_kb, p.total_cycles, s, p.l3_traffic_kb
        );
    }

    let t = |c: usize, l2: u64| {
        points
            .iter()
            .find(|p| p.cores == c && p.l2_kb == l2)
            .unwrap()
            .total_cycles as f64
    };
    println!(
        "\ncore-scaling saturation @256kB: 2->4 {:.2}x, 4->8 {:.2}x (paper: saturates beyond 4)",
        t(2, 256) / t(4, 256),
        t(4, 256) / t(8, 256)
    );

    bench("fig7/grid_search_9pts/case2", 2, 10, || {
        grid.run_canonical(g.clone(), &cfg).unwrap().len()
    });

    // a denser grid to show DSE throughput at scale
    let dense = GridSearch {
        base: presets::gap8(),
        cores: vec![1, 2, 3, 4, 6, 8],
        l2_kb: vec![128, 192, 256, 320, 384, 448, 512],
    };
    bench("fig7/grid_search_42pts/case2", 1, 5, || {
        dense.run_canonical(g.clone(), &cfg).unwrap().len()
    });
}
