//! Bench: DOM QONNX ingest vs the zero-allocation streaming pull path.
//!
//! Measures the full analyze-flow ingest — bytes on disk to a validated
//! [`Graph`](aladin::graph::ir::Graph) — three ways: the DOM path
//! (`Value::parse` + `QonnxModel::from_json`), streaming with lazy
//! payloads (`qonnx_stream::from_slice(_, DataPolicy::Lazy)`, initializer
//! `data` arrays recorded as byte spans and never decoded), and streaming
//! with eager payload decode. Throughput is reported in MB/s over the
//! document size.
//!
//! Three gates run in-bench; a violation panics, which fails the CI smoke
//! job:
//! 1. **Zero-allocation tokenizer**: a full pull-event scan of the
//!    document may heap-allocate at most a handful of times (scratch
//!    buffer growth on escaped strings) — never per token.
//! 2. **Allocation proportionality**: lazy ingest allocates roughly one
//!    source buffer plus model structure; the DOM path allocates a value
//!    tree per payload element. Lazy must stay far below DOM on both
//!    counters (an RSS proxy without OS-specific probes).
//! 3. **Bit-identity**: the eagerly streamed model must equal the DOM
//!    model (`PartialEq` decodes lazy spans, so payloads are compared by
//!    value).
//!
//! Document source: `BENCH_INGEST_MODEL=<path>` (CI generates a
//! ResNet-50-scale file via `python/compile/export_qonnx.py
//! --synthetic-scale`); without it a synthetic fallback is built
//! in-process from the exported LeNet with filled initializer payloads
//! plus an unknown-key calibration blob that streaming skips and DOM must
//! parse. `BENCH_TINY=1` shrinks the fallback; `BENCH_INGEST_JSON_OUT`
//! writes `BENCH_ingest.json`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

use aladin::graph::qonnx::{export, QonnxModel, TensorData};
use aladin::graph::qonnx_stream::{self, DataPolicy};
use aladin::models;
use aladin::util::bench::{bench, black_box, BenchStats};
use aladin::util::json::pull::{Event, PullParser};
use aladin::util::json::Value;

// ---- counting allocator: allocation-call / byte / peak instrumentation ----

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static CURRENT_BYTES: AtomicI64 = AtomicI64::new(0);
static PEAK_BYTES: AtomicI64 = AtomicI64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            note_alloc(layout.size() as i64);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        CURRENT_BYTES.fetch_sub(layout.size() as i64, Ordering::Relaxed);
        System.dealloc(ptr, layout);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            note_alloc(new_size as i64 - layout.size() as i64);
        }
        p
    }
}

fn note_alloc(delta: i64) {
    ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
    if delta > 0 {
        ALLOC_BYTES.fetch_add(delta as u64, Ordering::Relaxed);
    }
    let cur = CURRENT_BYTES.fetch_add(delta, Ordering::Relaxed) + delta;
    PEAK_BYTES.fetch_max(cur, Ordering::Relaxed);
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[derive(Debug, Clone, Copy)]
struct AllocStats {
    calls: u64,
    bytes: u64,
    peak_above_start: u64,
}

/// Run `f` once and report its allocator activity (single-threaded bench,
/// so the counters attribute cleanly).
fn measure_alloc<T>(f: impl FnOnce() -> T) -> (T, AllocStats) {
    let calls0 = ALLOC_CALLS.load(Ordering::Relaxed);
    let bytes0 = ALLOC_BYTES.load(Ordering::Relaxed);
    let start = CURRENT_BYTES.load(Ordering::Relaxed);
    PEAK_BYTES.store(start, Ordering::Relaxed);
    let out = f();
    let stats = AllocStats {
        calls: ALLOC_CALLS.load(Ordering::Relaxed) - calls0,
        bytes: ALLOC_BYTES.load(Ordering::Relaxed) - bytes0,
        peak_above_start: (PEAK_BYTES.load(Ordering::Relaxed) - start).max(0) as u64,
    };
    (out, stats)
}

// ---- synthetic fallback document -------------------------------------------

/// Exported LeNet with deterministic initializer payloads, padded to
/// roughly `target_bytes` with an unknown-key numeric blob (streaming
/// skips it structurally; the DOM path must build value nodes for it).
fn synthetic_doc(target_bytes: usize) -> String {
    let (g, _cfg) = models::lenet(8, (3, 32, 32), 10);
    let mut doc = export(&g);
    for t in doc.tensors.iter_mut() {
        if t.initializer {
            let n: usize = t.dims.iter().product();
            t.data =
                Some(TensorData::Inline((0..n).map(|i| (i as i64 % 251) - 125).collect()));
        }
    }
    let mut v = doc.to_json().expect("serialize synthetic model");
    let base_len = v.to_string_pretty().len();
    // each padded entry costs ~8 bytes of pretty-printed text
    let pad = target_bytes.saturating_sub(base_len) / 8;
    if let Value::Obj(fields) = &mut v {
        let blob: Vec<Value> = (0..pad).map(|i| Value::Num((i % 977) as f64)).collect();
        fields.push(("calibration_blob".to_string(), Value::Arr(blob)));
    }
    v.to_string_pretty()
}

fn stats_json(s: &BenchStats) -> Value {
    Value::obj()
        .with("name", s.name.clone())
        .with("iters", s.iters)
        .with("min_us", s.min.as_micros() as u64)
        .with("median_us", s.median.as_micros() as u64)
        .with("mean_us", s.mean.as_micros() as u64)
        .with("max_us", s.max.as_micros() as u64)
}

fn alloc_json(a: &AllocStats) -> Value {
    Value::obj()
        .with("calls", a.calls)
        .with("bytes", a.bytes)
        .with("peak_above_start_bytes", a.peak_above_start)
}

fn mb_per_s(bytes: usize, s: &BenchStats) -> f64 {
    bytes as f64 / 1e6 / s.median.as_secs_f64().max(1e-12)
}

fn main() {
    let tiny =
        std::env::var("BENCH_TINY").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let (source, text) = match std::env::var("BENCH_INGEST_MODEL") {
        Ok(path) => {
            let text = std::fs::read_to_string(&path).expect("read BENCH_INGEST_MODEL");
            (path, text)
        }
        Err(_) => {
            let target = if tiny { 3 << 20 } else { 48 << 20 };
            ("synthetic".to_string(), synthetic_doc(target))
        }
    };
    let bytes = text.as_bytes();
    let total = bytes.len();
    let iters = if tiny { 5 } else { 3 };
    println!(
        "=== ingest: DOM vs streaming pull parser ({source}, {:.2} MB{}) ===",
        total as f64 / 1e6,
        if tiny { ", tiny" } else { "" }
    );

    // gate 1: a pure event scan never allocates per token
    let (events, scan_alloc) = measure_alloc(|| {
        let mut p = PullParser::new(bytes);
        let mut n = 0u64;
        loop {
            match p.next_event().expect("scan document") {
                Event::End => break,
                _ => n += 1,
            }
        }
        n
    });
    println!(
        "pull scan: {events} events, {} allocator calls ({} bytes)",
        scan_alloc.calls, scan_alloc.bytes
    );
    assert!(
        scan_alloc.calls <= 64,
        "tokenizer allocated {} times over {events} events — not zero-allocation",
        scan_alloc.calls
    );

    let dom_ingest = || {
        let v = Value::parse(&text).expect("DOM parse");
        let doc = QonnxModel::from_json(&v).expect("DOM decode");
        doc.to_graph().expect("analyze entry").nodes.len()
    };
    let lazy_ingest = || {
        let doc = qonnx_stream::from_slice(bytes, DataPolicy::Lazy).expect("stream lazy");
        doc.to_graph().expect("analyze entry").nodes.len()
    };
    let eager_ingest = || {
        let doc = qonnx_stream::from_slice(bytes, DataPolicy::Eager).expect("stream eager");
        doc.to_graph().expect("analyze entry").nodes.len()
    };

    let dom = bench("ingest/dom_value_tree", 1, iters, dom_ingest);
    let lazy = bench("ingest/stream_lazy", 1, iters, lazy_ingest);
    let eager = bench("ingest/stream_eager", 1, iters, eager_ingest);

    // gate 2: allocation proportionality (peak-RSS proxy)
    let (_, dom_alloc) = measure_alloc(|| black_box(dom_ingest()));
    let (_, lazy_alloc) = measure_alloc(|| black_box(lazy_ingest()));
    println!(
        "allocations: dom {} calls / {:.1} MB peak, lazy {} calls / {:.1} MB peak",
        dom_alloc.calls,
        dom_alloc.peak_above_start as f64 / 1e6,
        lazy_alloc.calls,
        lazy_alloc.peak_above_start as f64 / 1e6
    );
    assert!(
        lazy_alloc.calls < dom_alloc.calls,
        "lazy ingest made {} allocator calls vs DOM {} — expected fewer",
        lazy_alloc.calls,
        dom_alloc.calls
    );
    // lazy holds one owned copy of the source (from_slice -> Vec, moved
    // into the Arc without copying) plus model structure; the DOM value
    // tree dwarfs that on payload-heavy documents
    assert!(
        lazy_alloc.peak_above_start < total as u64 + total as u64 / 4 + (1 << 22),
        "lazy ingest peaked at {} bytes over a {total}-byte document",
        lazy_alloc.peak_above_start
    );
    assert!(
        lazy_alloc.peak_above_start * 2 < dom_alloc.peak_above_start,
        "lazy peak {} not well below DOM peak {} — payload is being materialized",
        lazy_alloc.peak_above_start,
        dom_alloc.peak_above_start
    );

    // gate 3: bit-identity between the DOM and streamed models
    let v = Value::parse(&text).expect("DOM parse");
    let dom_model = QonnxModel::from_json(&v).expect("DOM decode");
    let eager_model =
        qonnx_stream::from_slice(bytes, DataPolicy::Eager).expect("stream eager");
    let lazy_model = qonnx_stream::from_slice(bytes, DataPolicy::Lazy).expect("stream lazy");
    assert_eq!(dom_model, eager_model, "eager streamed model diverged from DOM");
    assert_eq!(dom_model, lazy_model, "lazy streamed model diverged from DOM");

    let dom_rate = mb_per_s(total, &dom);
    let lazy_rate = mb_per_s(total, &lazy);
    let eager_rate = mb_per_s(total, &eager);
    let speedup = lazy_rate / dom_rate.max(1e-12);
    println!(
        "\nthroughput: dom {dom_rate:.1} MB/s, stream-lazy {lazy_rate:.1} MB/s \
         ({speedup:.1}x), stream-eager {eager_rate:.1} MB/s, models bit-identical"
    );
    if total >= 1 << 20 {
        assert!(
            speedup >= 5.0,
            "streaming lazy ingest is only {speedup:.2}x over DOM (need >=5x)"
        );
    }

    if let Ok(path) = std::env::var("BENCH_INGEST_JSON_OUT") {
        let doc = Value::obj()
            .with("bench", "ingest")
            .with("tiny", tiny)
            .with("source", source)
            .with("bytes", total as u64)
            .with("events", events)
            .with("dom_mb_per_s", dom_rate)
            .with("stream_lazy_mb_per_s", lazy_rate)
            .with("stream_eager_mb_per_s", eager_rate)
            .with("speedup", speedup)
            .with("bit_identical", true)
            .with("scan_alloc_calls", scan_alloc.calls)
            .with("dom_alloc", alloc_json(&dom_alloc))
            .with("lazy_alloc", alloc_json(&lazy_alloc))
            .with(
                "runs",
                Value::Arr(vec![stats_json(&dom), stats_json(&lazy), stats_json(&eager)]),
            );
        std::fs::write(&path, doc.to_string_pretty()).expect("write ingest bench json");
        println!("wrote ingest bench timings to {path}");
    }
}
