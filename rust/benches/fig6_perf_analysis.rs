//! Bench: Fig. 6 — platform-aware simulation of Cases 1-3 on GAP8.
//!
//! Regenerates the per-layer cycles + L1/L2 utilization comparison of
//! paper Fig. 6 and times the platform-aware half of the pipeline
//! (fusion + tiling + scheduling + cycle simulation).

use aladin::coordinator::Pipeline;
use aladin::impl_aware::decorate;
use aladin::models;
use aladin::platform::presets;
use aladin::platform_aware::{build_schedule, fuse};
use aladin::sim::{report, simulate};
use aladin::util::bench::bench;

fn main() {
    println!("=== Fig. 6: platform-aware performance analysis (GAP8) ===");

    let mut sims = Vec::new();
    for case in models::all_cases() {
        let (g, cfg) = case.build();
        let a = Pipeline::new(presets::gap8(), cfg).analyze(g).unwrap();
        sims.push(a.sim);
    }
    let refs: Vec<&aladin::sim::SimResult> = sims.iter().collect();
    print!(
        "{}",
        report::render_comparison(&["case1", "case2", "case3"], &refs)
    );

    // the §VIII-B headline comparisons
    let cyc = |i: usize, layer: &str| {
        sims[i]
            .layers
            .iter()
            .find(|l| l.name == layer)
            .map(|l| l.cycles)
            .unwrap_or(0)
    };
    println!(
        "\nint4-vs-int8 im2col (RC_2): case2/case1 = {:.2} (paper: ~1, unpack overhead)",
        cyc(1, "RC_2") as f64 / cyc(0, "RC_2") as f64
    );
    println!(
        "2-bit vs 4-bit LUT (RC_21): case3/case2 = {:.2} (paper: ~1, shared-LUT contention)",
        cyc(2, "RC_21") as f64 / cyc(1, "RC_21").max(1) as f64
    );

    // timing: the simulation half alone, per case
    for case in models::all_cases() {
        let name = case.name.clone();
        let (g, cfg) = case.build();
        let decorated = decorate(g, &cfg).unwrap();
        let platform = std::sync::Arc::new(presets::gap8());
        bench(&format!("fig6/fuse+tile+simulate/{name}"), 3, 20, || {
            let schedule = build_schedule(&fuse(&decorated).unwrap(), &platform).unwrap();
            simulate(&schedule).total_cycles()
        });
    }
}
