//! Bench: scalar reference interpreter vs the batched im2col/GEMM executor.
//!
//! Measures the integer forward pass of the decorated LeNet in vectors/sec
//! on the scalar golden path (one vector at a time through
//! `run_int_edges_in`) against the data-oriented batched path
//! (`run_int_batched_outputs`: SoA vector batches, one GEMM per layer,
//! `std::thread::scope` workers). Lowering and float calibration happen
//! once, outside the timed region, so the numbers isolate interpreter
//! throughput.
//!
//! Bit-identity is asserted in-bench: every per-vector batched output must
//! equal the scalar output, and the `measure_scalar` / `measure_batched`
//! records must carry the same fingerprint — a mismatch panics, which
//! fails the CI smoke job.
//!
//! CI smoke mode: `BENCH_TINY=1` shrinks the vector set so the bench runs
//! in seconds, and `BENCH_INTERP_JSON_OUT=<path>` writes the throughputs
//! as a JSON artifact (`BENCH_interp.json`) with keys
//! `scalar_vectors_per_sec`, `batched_vectors_per_sec`, `speedup`,
//! `threads`.

use std::sync::Arc;

use aladin::exec::{measure_batched, measure_scalar, Executable, Scratch};
use aladin::impl_aware::decorate;
use aladin::models;
use aladin::util::bench::{bench, BenchStats};
use aladin::util::json::Value;

fn stats_json(s: &BenchStats) -> Value {
    Value::obj()
        .with("name", s.name.clone())
        .with("iters", s.iters)
        .with("min_us", s.min.as_micros() as u64)
        .with("median_us", s.median.as_micros() as u64)
        .with("mean_us", s.mean.as_micros() as u64)
        .with("max_us", s.max.as_micros() as u64)
}

fn main() {
    let tiny = std::env::var("BENCH_TINY").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let n_vectors = if tiny { 32 } else { 128 };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 8);
    println!(
        "=== interpreter: scalar reference vs batched im2col GEMM \
         (lenet_int8, {n_vectors} vectors, {threads} threads{}) ===",
        if tiny { ", tiny" } else { "" }
    );

    let (g, cfg) = models::lenet(8, (3, 32, 32), 10);
    let graph = Arc::new(decorate(g, &cfg).unwrap());
    let vectors = models::lenet_vectors(n_vectors);
    let exe = Executable::lower(graph.clone(), &vectors).unwrap();

    // scalar golden path: one vector at a time, shared scratch arena
    let scalar_outputs = |exe: &Executable| {
        let mut scratch = Scratch::new();
        vectors
            .inputs
            .iter()
            .map(|v| exe.run_int_in(v, &mut scratch).unwrap())
            .collect::<Vec<_>>()
    };
    let scalar = bench("interp/scalar_reference", 1, 5, || scalar_outputs(&exe).len());

    // batched path: SoA batches over the same executable, worker threads
    let batched = bench("interp/batched_gemm", 1, 5, || {
        exe.run_int_batched_outputs(&vectors.inputs, threads).unwrap().len()
    });

    // bit-identity gate: per-vector outputs and the full measured records
    let scalar_outs = scalar_outputs(&exe);
    let batched_outs = exe.run_int_batched_outputs(&vectors.inputs, threads).unwrap();
    assert_eq!(
        scalar_outs, batched_outs,
        "batched interpreter output diverged from the scalar reference"
    );
    let rs = measure_scalar(graph.clone(), &vectors).unwrap();
    let rb = measure_batched(graph, &vectors, threads).unwrap();
    assert_eq!(
        rs.output_fingerprint, rb.output_fingerprint,
        "measure_scalar / measure_batched fingerprints diverged"
    );
    assert_eq!(rs.matches, rb.matches, "top-1 match counts diverged");

    let n = n_vectors as f64;
    let scalar_rate = n / scalar.median.as_secs_f64().max(1e-12);
    let batched_rate = n / batched.median.as_secs_f64().max(1e-12);
    let speedup = batched_rate / scalar_rate;
    println!(
        "\nthroughput: scalar {scalar_rate:.1} vectors/sec, batched {batched_rate:.1} \
         vectors/sec ({speedup:.2}x at {threads} threads), outputs bit-identical \
         (fingerprint {:016x})",
        rb.output_fingerprint
    );

    if let Ok(path) = std::env::var("BENCH_INTERP_JSON_OUT") {
        let doc = Value::obj()
            .with("bench", "interp_batch")
            .with("tiny", tiny)
            .with("model", "lenet_int8")
            .with("n_vectors", n_vectors)
            .with("threads", threads)
            .with("scalar_vectors_per_sec", scalar_rate)
            .with("batched_vectors_per_sec", batched_rate)
            .with("speedup", speedup)
            .with("bit_identical", true)
            .with("output_fingerprint", format!("{:016x}", rb.output_fingerprint))
            .with("runs", Value::Arr(vec![stats_json(&scalar), stats_json(&batched)]));
        std::fs::write(&path, doc.to_string_pretty()).expect("write interp bench json");
        println!("wrote interpreter bench timings to {path}");
    }
}
