//! Ablation benches for the design choices DESIGN.md calls out:
//! double buffering, LUT bank contention, and L3 weight prefetch.
//! Each ablation disables one mechanism and reports the cycle delta on the
//! Table-I cases — quantifying how much each mechanism contributes to the
//! simulated latency (and therefore to the paper's observations).

use aladin::impl_aware::decorate;
use aladin::models;
use aladin::platform::presets;
use aladin::platform_aware::{build_schedule, fuse};
use aladin::sim::simulate;
use std::sync::Arc;

fn main() {
    println!("=== ablations: per-mechanism contribution to simulated latency ===\n");
    println!(
        "{:<8} {:>14} {:>16} {:>16} {:>16}",
        "case", "baseline", "-double-buffer", "-LUT-contention", "-L3-prefetch"
    );

    for case in models::all_cases() {
        let name = case.name.clone();
        let (g, cfg) = case.build();
        let decorated = decorate(g, &cfg).unwrap();
        let layers = fuse(&decorated).unwrap();
        let platform = Arc::new(presets::gap8());

        let baseline = simulate(&build_schedule(&layers, &platform).unwrap()).total_cycles();

        // ablation 1: no double buffering (single-buffered tiles)
        let mut s = build_schedule(&layers, &platform).unwrap();
        for l in &mut s.layers {
            l.tile.double_buffered = false;
        }
        let no_db = simulate(&s).total_cycles();

        // ablation 2: no LUT bank contention (pretend the table spans all
        // banks — the replicated-LUT architecture of [21])
        let mut p2 = (*platform).clone();
        p2.l1_banks = 16;
        let p2 = Arc::new(p2);
        let mut s2 = build_schedule(&layers, &p2).unwrap();
        // emulate "replicated LUT": temp bits spread over whole L1
        for l in &mut s2.layers {
            if l.layer.uses_mul_lut() {
                l.layer.temp_bits = p2.l1_bytes * 8; // spans all banks
            }
        }
        let no_contention = simulate(&s2).total_cycles();

        // ablation 3: no L3 prefetch overlap
        let mut s3 = build_schedule(&layers, &platform).unwrap();
        for l in &mut s3.layers {
            l.l2.prefetchable = false;
        }
        let no_prefetch = simulate(&s3).total_cycles();

        println!(
            "{:<8} {:>14} {:>13} (+{:>4.1}%) {:>12} ({:>+5.1}%) {:>11} (+{:>4.1}%)",
            name,
            baseline,
            no_db,
            (no_db as f64 / baseline as f64 - 1.0) * 100.0,
            no_contention,
            (no_contention as f64 / baseline as f64 - 1.0) * 100.0,
            no_prefetch,
            (no_prefetch as f64 / baseline as f64 - 1.0) * 100.0,
        );
    }

    println!(
        "\n(-LUT-contention emulates the replicated-LUT design of [21]: LUT layers \
         stop contending,\n so case2/case3 speed up; case1 is unaffected.)"
    );
}
