//! Microbenchmarks of the analysis pipeline stages — the §Perf
//! (EXPERIMENTS.md) measurement harness: decoration, fusion, tiling,
//! simulation, and the end-to-end pipeline, on the full-width Case 1.

use aladin::coordinator::Pipeline;
use aladin::impl_aware::decorate;
use aladin::models;
use aladin::platform::presets;
use aladin::platform_aware::{build_schedule, fuse, plan_layer};
use aladin::sim::simulate;
use aladin::util::bench::bench;
use std::sync::Arc;

fn main() {
    println!("=== pipeline stage microbenchmarks (Case 1, width 1.0) ===");
    let case = models::case1();
    let (g, cfg) = case.build();
    let platform = Arc::new(presets::gap8());

    bench("stage/build_graph", 3, 30, || models::case1().build().0.nodes.len());

    bench("stage/decorate", 3, 30, || {
        decorate(g.clone(), &cfg).unwrap().nodes.len()
    });

    let decorated = decorate(g.clone(), &cfg).unwrap();
    bench("stage/fuse", 3, 50, || fuse(&decorated).unwrap().len());

    let layers = fuse(&decorated).unwrap();
    bench("stage/tiling_all_layers", 3, 50, || {
        layers
            .iter()
            .map(|l| plan_layer(l, &platform).unwrap().n_tiles())
            .sum::<usize>()
    });

    bench("stage/build_schedule", 3, 50, || {
        build_schedule(&layers, &platform).unwrap().layers.len()
    });

    let schedule = build_schedule(&layers, &platform).unwrap();
    bench("stage/simulate", 3, 50, || simulate(&schedule).total_cycles());

    bench("e2e/full_pipeline_case1", 2, 20, || {
        let (g, cfg) = models::case1().build();
        Pipeline::new((*platform).clone(), cfg)
            .analyze(g)
            .unwrap()
            .latency
            .total_cycles
    });

    // worst case for the tiling solver: very wide layer on a tiny L1
    let mut small = presets::gap8();
    small.l1_bytes = 8 * 1024;
    small.l1_banks = 8;
    bench("stage/tiling_tiny_l1", 2, 10, || {
        layers
            .iter()
            .filter_map(|l| plan_layer(l, &small).ok())
            .map(|p| p.n_tiles())
            .sum::<usize>()
    });
}
