//! Bench: Table I — the three mixed-precision/implementation cases.
//!
//! Regenerates the Table-I structure, the model-derived columns (parameter
//! memory, latency bound), and — when `artifacts/` exists — the measured
//! accuracy column via the PJRT runtime. Times the full per-case pipeline.

use aladin::coordinator::Pipeline;
use aladin::models;
use aladin::platform::presets;
use aladin::runtime;
use aladin::util::bench::bench;

fn main() {
    println!("=== Table I: cases, accuracy, latency ===\n");
    println!(
        "{:<12} {:>14} {:>14} {:>14}",
        "Block", "Case 1", "Case 2", "Case 3"
    );
    for r in models::table1_rows() {
        println!(
            "{:<12} {:>14} {:>14} {:>14}",
            r.block, r.case1, r.case2, r.case3
        );
    }

    // measured accuracy (Table I bottom row) if artifacts are built
    let accuracy: Vec<Option<f64>> = match runtime::Manifest::load("artifacts")
        .and_then(|m| runtime::Engine::cpu().and_then(|e| runtime::evaluate_all(&e, &m)))
    {
        Ok(reports) => ["case1", "case2", "case3"]
            .iter()
            .map(|n| reports.iter().find(|r| &r.model == n).map(|r| r.accuracy))
            .collect(),
        Err(e) => {
            println!("\n(accuracy column skipped: {e})");
            vec![None, None, None]
        }
    };

    let mut row_acc = String::from("Accuracy    ");
    let mut row_paper = String::from("Paper acc.  ");
    for (i, (name, paper)) in models::PAPER_ACCURACY.iter().enumerate() {
        let _ = name;
        match accuracy[i] {
            Some(a) => row_acc.push_str(&format!(" {a:>13.4}")),
            None => row_acc.push_str(&format!(" {:>13}", "-")),
        }
        row_paper.push_str(&format!(" {paper:>13.2}"));
    }
    println!("{row_acc}\n{row_paper}");

    println!("\nmodel-derived columns:");
    println!(
        "{:<8} {:>12} {:>14} {:>12}",
        "case", "params kB", "cycles", "latency ms"
    );
    for case in models::all_cases() {
        let name = case.name.clone();
        let (g, cfg) = case.build();
        let a = Pipeline::new(presets::gap8(), cfg.clone()).analyze(g.clone()).unwrap();
        println!(
            "{:<8} {:>12.1} {:>14} {:>12.3}",
            name,
            a.impl_summary.iter().map(|r| r.param_mem_bits).sum::<u64>() as f64 / 8192.0,
            a.latency.total_cycles,
            a.latency.latency_s * 1e3
        );
        bench(&format!("table1/full_pipeline/{name}"), 2, 10, || {
            Pipeline::new(presets::gap8(), cfg.clone())
                .analyze(g.clone())
                .unwrap()
                .latency
                .total_cycles
        });
    }
}
