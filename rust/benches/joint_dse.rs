//! Bench: the unified joint quantization × hardware DSE engine.
//!
//! Measures (a) the Fig. 7 hardware grid evaluated the old way — one full
//! parse→decorate→fuse→tile→simulate pipeline per candidate, sequentially —
//! against the cache-backed parallel engine, in candidates/sec; and (b) the
//! joint quant×hardware product space (`aladin dse --joint`) where the
//! cache collapses the per-quant-config decoration across every hardware
//! point. Also prints the stage-recomputation accounting that the
//! `engine_cache` integration test asserts.
//!
//! CI smoke mode: `BENCH_TINY=1` shrinks the workload (width-mult 0.25) so
//! the bench runs in seconds, and `BENCH_JSON_OUT=<path>` writes the
//! timings + cache counters as a JSON artifact (`BENCH_joint_dse.json`) so
//! the per-PR perf trajectory accumulates.

use aladin::analysis::{lint_model, LintConfig};
use aladin::coordinator::Pipeline;
use aladin::dse::{
    evolve, explore_joint, normalized_front_hypervolume, objectives, EvalEngine, EvoConfig,
    Genome, GridSearch, HwAxis, JointSpace, SearchSpace,
};
use aladin::impl_aware::decorate;
use aladin::platform_aware::fuse;
use aladin::models;
use aladin::models::BlockImpl;
use aladin::platform::presets;
use aladin::sim::BackendKind;
use aladin::util::bench::{bench, BenchStats};
use aladin::util::json::Value;
use aladin::util::prng::Prng;
use aladin::util::ToJson;

fn stats_json(s: &BenchStats) -> Value {
    Value::obj()
        .with("name", s.name.clone())
        .with("iters", s.iters)
        .with("min_us", s.min.as_micros() as u64)
        .with("median_us", s.median.as_micros() as u64)
        .with("mean_us", s.mean.as_micros() as u64)
        .with("max_us", s.max.as_micros() as u64)
}

fn main() {
    let tiny = std::env::var("BENCH_TINY").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    println!(
        "=== joint DSE: sequential pipeline vs cache-backed engine (Case 2{}) ===",
        if tiny { ", tiny grid" } else { "" }
    );

    let mut case = models::case2();
    if tiny {
        case.width_mult = 0.25;
    }
    let (g, cfg) = case.build();
    let grid_points: Vec<(usize, u64)> = [2usize, 4, 8]
        .iter()
        .flat_map(|&c| [256u64, 320, 512].iter().map(move |&l2| (c, l2)))
        .collect();

    // (a) sequential baseline: the pre-engine behaviour — every candidate
    // re-runs the whole pipeline from the canonical graph
    let seq = bench("joint_dse/fig7_9pts/sequential_pipeline", 1, 5, || {
        let mut total = 0u64;
        for &(c, l2) in &grid_points {
            let a = Pipeline::new(presets::gap8_with(c, l2), cfg.clone())
                .analyze(g.clone())
                .unwrap();
            total += a.latency.total_cycles;
        }
        total
    });

    // (b) the engine: stage-1 shared, stage-2 parallel across the grid
    let eng = bench("joint_dse/fig7_9pts/eval_engine", 1, 5, || {
        GridSearch::fig7(presets::gap8())
            .run_canonical(g.clone(), &cfg)
            .unwrap()
            .len()
    });

    let n = grid_points.len() as f64;
    let seq_rate = n / seq.median.as_secs_f64();
    let eng_rate = n / eng.median.as_secs_f64();
    println!(
        "\nFig. 7 grid throughput: sequential {seq_rate:.2} candidates/sec, \
         engine {eng_rate:.2} candidates/sec ({:.2}x)",
        eng_rate / seq_rate
    );

    // recomputation accounting on a persistent engine
    let decorated = decorate(g.clone(), &cfg).unwrap();
    let engine = EvalEngine::for_decorated(decorated, presets::gap8());
    let pts = GridSearch::fig7(presets::gap8()).run_on(&engine).unwrap();
    let s = engine.stats();
    println!(
        "Fig. 7 grid recomputation: {} pipeline-stage computations for {} \
         candidates x 2 stages ({} uncached) — stage-1 {}x, stage-2 {}x",
        s.recomputations(),
        pts.len(),
        s.naive_recomputations(),
        s.impl_computed,
        s.sim_computed
    );
    assert!(
        s.recomputations() < pts.len() * 2,
        "cache must beat point-count x stage-count"
    );

    // (c) the joint quant x hardware product space: 2 quant configs x 9
    // hardware points; each quant config is decorated exactly once
    let space = JointSpace::default_grid();
    let case_for_joint = case.clone();
    let joint_bench = bench("joint_dse/joint_18cand/case2", 1, 3, || {
        explore_joint(case_for_joint.clone(), presets::gap8(), &space, None)
            .unwrap()
            .records
            .len()
    });
    let joint = explore_joint(case.clone(), presets::gap8(), &space, None).unwrap();
    let js = joint.stats;
    println!(
        "joint space: {} candidates, Pareto front {} — {} stage computations \
         ({} uncached): stage-1 {}x for {} quant configs, stage-2 {}x",
        joint.records.len(),
        joint.front.len(),
        js.recomputations(),
        js.naive_recomputations(),
        js.impl_computed,
        space.quant_axes(10).len(),
        js.sim_computed
    );

    // (d) evolutionary search vs exhaustive enumeration. Front quality is
    // compared on the tiny uniform grid (18 candidates, ground truth
    // enumerable); throughput is additionally measured on a per-layer
    // space far beyond enumeration (6^10 x 9 ≈ 5.4e8 points).
    let exhaustive_rate = joint.records.len() as f64 / joint_bench.median.as_secs_f64();
    let small_space = SearchSpace {
        bits: space.bits.clone(),
        impls: space.impls.clone(),
        n_blocks: 10,
        cores: space.cores.clone(),
        l2_kb: space.l2_kb.clone(),
        backends: vec![],
    };
    let evo_cfg_small = EvoConfig {
        population: 24,
        generations: 3,
        seed: 17,
        max_evals: 200,
        ..EvoConfig::default()
    };
    let case_evo = case.clone();
    let evo_small_bench = bench("joint_dse/evo_small_grid/case2", 1, 3, || {
        let engine = EvalEngine::for_mobilenet(case_evo.clone(), presets::gap8());
        evolve(&engine, &small_space, &evo_cfg_small).unwrap().evaluations
    });
    let engine = EvalEngine::for_mobilenet(case.clone(), presets::gap8());
    let evo_small = evolve(&engine, &small_space, &evo_cfg_small).unwrap();
    let evo_small_rate = evo_small.evaluations as f64 / evo_small_bench.median.as_secs_f64();

    // shared normalization so the two hypervolumes are comparable
    let exh_pts: Vec<[f64; 4]> = joint.records.iter().map(objectives).collect();
    let evo_pts: Vec<[f64; 4]> = evo_small.records.iter().map(objectives).collect();
    let mut union = exh_pts.clone();
    union.extend(evo_pts);
    let exh_hv = normalized_front_hypervolume(&union, &joint.front);
    let evo_front_shifted: Vec<usize> =
        evo_small.front.iter().map(|&i| i + exh_pts.len()).collect();
    let evo_hv = normalized_front_hypervolume(&union, &evo_front_shifted);
    println!(
        "evo vs exhaustive (tiny grid): exhaustive {exhaustive_rate:.2} cand/s hv {exh_hv:.4}, \
         evo {evo_small_rate:.2} cand/s hv {evo_hv:.4} ({} evals, {} pruned)",
        evo_small.evaluations,
        evo_small.pruned.len()
    );

    let big_space = SearchSpace {
        bits: vec![2, 4, 8],
        impls: vec![BlockImpl::Im2col, BlockImpl::Lut],
        n_blocks: 10,
        cores: vec![2, 4, 8],
        l2_kb: vec![256, 320, 512],
        backends: vec![],
    };
    // big_space has 54 uniform seed genomes (3 bits x 2 impls x 9 hw), so
    // the budget must exceed 54 or generation 0 exhausts it before any
    // crossover/mutation runs and the metrics measure seed enumeration
    let evo_cfg_big = EvoConfig {
        population: 16,
        generations: 8,
        seed: 23,
        max_evals: if tiny { 80 } else { 160 },
        ..EvoConfig::default()
    };
    let t0 = std::time::Instant::now();
    let engine = EvalEngine::for_mobilenet(case.clone(), presets::gap8());
    let evo_big = evolve(&engine, &big_space, &evo_cfg_big).unwrap();
    let big_secs = t0.elapsed().as_secs_f64();
    let evo_big_rate = evo_big.evaluations as f64 / big_secs.max(1e-12);
    let big_pts: Vec<[f64; 4]> = evo_big.records.iter().map(objectives).collect();
    let big_hv = normalized_front_hypervolume(&big_pts, &evo_big.front);
    println!(
        "evo on {:.3e}-point space: {} evals in {big_secs:.2}s ({evo_big_rate:.2} cand/s), \
         front {} hv {big_hv:.4}, {} pruned unevaluated",
        big_space.size(),
        evo_big.evaluations,
        evo_big.front.len(),
        evo_big.pruned.len()
    );

    // (e) layer-grained incremental evaluation on the evo mutation
    // workload: a chain of 1–2-gene offspring evaluated via the delta path
    // (one warm engine, evaluate_delta against the parent) vs the
    // full-recompute path (a cold engine per candidate — what every
    // distinct genome cost before the layer-grained tier)
    let mutation_space = SearchSpace {
        bits: vec![2, 4, 8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![2, 4, 8],
        l2_kb: vec![256, 320, 512],
        backends: vec![],
    };
    let chain_len = if tiny { 8 } else { 16 };
    let mut rng = Prng::new(41);
    let mut chain: Vec<Genome> = Vec::with_capacity(chain_len + 1);
    chain.push(Genome::uniform(
        8,
        BlockImpl::Im2col,
        10,
        Some(HwAxis { cores: 8, l2_kb: 512, backend: None }),
    ));
    while chain.len() <= chain_len {
        let mut next = chain.last().unwrap().clone();
        mutation_space.mutate(&mut next, &mut rng, 0.12);
        if next.key() != chain.last().unwrap().key() {
            chain.push(next);
        }
    }

    // full recompute: every mutant pays the whole pipeline from the root
    let t0 = std::time::Instant::now();
    let mut full_cycles: Vec<u64> = Vec::with_capacity(chain_len);
    for g in &chain[1..] {
        let cold = EvalEngine::for_mobilenet(case.clone(), presets::gap8()).with_threads(1);
        full_cycles.push(cold.evaluate(&g.vector()).unwrap().total_cycles);
    }
    let full_secs = t0.elapsed().as_secs_f64();

    // incremental: one warm engine, each offspring diffed against its parent
    let warm = EvalEngine::for_mobilenet(case.clone(), presets::gap8()).with_threads(1);
    warm.evaluate(&chain[0].vector()).unwrap();
    let t0 = std::time::Instant::now();
    let mut inc_cycles: Vec<u64> = Vec::with_capacity(chain_len);
    for w in chain.windows(2) {
        inc_cycles.push(
            warm.evaluate_delta(&w[0].vector(), &w[1].vector())
                .unwrap()
                .total_cycles,
        );
    }
    let inc_secs = t0.elapsed().as_secs_f64();
    assert_eq!(full_cycles, inc_cycles, "incremental path must be bit-identical");

    let full_rate = chain_len as f64 / full_secs.max(1e-12);
    let inc_rate = chain_len as f64 / inc_secs.max(1e-12);
    let warm_stats = warm.stats();
    println!(
        "incremental vs full on {chain_len} mutation offspring: full {full_rate:.2} cand/s, \
         incremental {inc_rate:.2} cand/s ({:.2}x) — layer units {} computed / {} spliced, \
         {} incremental re-decorations reusing {} node decorations",
        inc_rate / full_rate,
        warm_stats.layer_computed,
        warm_stats.layer_hits,
        warm_stats.impl_delta,
        warm_stats.nodes_reused
    );

    if let Ok(path) = std::env::var("BENCH_INCR_JSON_OUT") {
        let doc = Value::obj()
            .with("bench", "incremental_dse")
            .with("tiny", tiny)
            .with("width_mult", case.width_mult)
            .with("chain_len", chain_len)
            .with("full_cand_per_sec", full_rate)
            .with("incremental_cand_per_sec", inc_rate)
            .with("speedup", inc_rate / full_rate)
            .with("bit_identical", true)
            .with("cache_stats", warm_stats.to_json());
        std::fs::write(&path, doc.to_string_pretty()).expect("write incremental bench json");
        println!("wrote incremental bench timings to {path}");
    }

    if let Ok(path) = std::env::var("BENCH_SEARCH_JSON_OUT") {
        let doc = Value::obj()
            .with("bench", "search_dse")
            .with("tiny", tiny)
            .with("width_mult", case.width_mult)
            .with("exhaustive_cand_per_sec", exhaustive_rate)
            .with("exhaustive_front_hypervolume", exh_hv)
            .with("exhaustive_candidates", joint.records.len())
            .with("evo_cand_per_sec", evo_small_rate)
            .with("evo_front_hypervolume", evo_hv)
            .with("evo_evaluations", evo_small.evaluations)
            .with("evo_pruned", evo_small.pruned.len())
            .with("big_space_points", big_space.size())
            .with("big_evo_cand_per_sec", evo_big_rate)
            .with("big_evo_front_hypervolume", big_hv)
            .with("big_evo_evaluations", evo_big.evaluations)
            .with("big_evo_front", evo_big.front.len())
            .with("big_evo_pruned", evo_big.pruned.len())
            .with(
                "runs",
                Value::Arr(vec![stats_json(&joint_bench), stats_json(&evo_small_bench)]),
            );
        std::fs::write(&path, doc.to_string_pretty()).expect("write search bench json");
        println!("wrote search bench timings to {path}");
    }

    // (f) backend matrix: the Fig. 7 grid under each hardware backend, one
    // shared decorated graph and a per-backend platform clone — the same
    // split `aladin dse --backend all` performs. Headline per backend: the
    // best-latency grid point and its modeled energy.
    if let Ok(path) = std::env::var("BENCH_BACKENDS_JSON_OUT") {
        println!("\n=== backend matrix: Fig. 7 grid per hardware backend ===");
        let decorated = decorate(g.clone(), &cfg).unwrap();
        let mut rows = Vec::new();
        for kind in BackendKind::all() {
            let mut platform = presets::gap8();
            platform.backend = kind;
            let engine = EvalEngine::for_decorated(decorated.clone(), platform.clone());
            let t0 = std::time::Instant::now();
            let points = GridSearch::fig7(platform).run_on(&engine).unwrap();
            let secs = t0.elapsed().as_secs_f64();
            let stats = engine.stats();
            let best = points
                .iter()
                .min_by_key(|p| p.total_cycles)
                .expect("fig7 grid is non-empty");
            println!(
                "{:<11} {} points in {secs:.2}s — best {}c/{}kB: {} cycles, {:.3} ms, {:.1} uJ",
                kind.label(),
                points.len(),
                best.cores,
                best.l2_kb,
                best.total_cycles,
                best.latency_s * 1e3,
                best.energy_nj / 1e3
            );
            rows.push(
                Value::obj()
                    .with("backend", kind.label())
                    .with("grid_points", points.len())
                    .with("grid_secs", secs)
                    .with("best_cores", best.cores)
                    .with("best_l2_kb", best.l2_kb)
                    .with("best_total_cycles", best.total_cycles)
                    .with("best_latency_s", best.latency_s)
                    .with("best_energy_nj", best.energy_nj)
                    .with("cache_stats", stats.to_json()),
            );
        }
        let doc = Value::obj()
            .with("bench", "backend_matrix")
            .with("tiny", tiny)
            .with("width_mult", case.width_mult)
            .with("backends", Value::Arr(rows));
        std::fs::write(&path, doc.to_string_pretty()).expect("write backend bench json");
        println!("wrote backend matrix to {path}");
    }

    // (g) the static lint screen: raw lint throughput (models/sec) on the
    // Fig. 7 grid crossed with every backend, and the screen's prune rate
    // on an evolutionary run whose seeds include statically infeasible
    // hardware corners (sharded backend at 1 core -> blocking AL103)
    let lint_decorated = decorate(g.clone(), &cfg).unwrap();
    let lint_fused = fuse(&lint_decorated).unwrap();
    let lint_platforms: Vec<_> = BackendKind::all()
        .iter()
        .flat_map(|&kind| {
            grid_points.iter().map(move |&(c, l2)| {
                let mut p = presets::gap8_with(c, l2);
                p.backend = kind;
                p
            })
        })
        .collect();
    let lint_bench = bench("joint_dse/lint/fig7_x_backends", 1, 5, || {
        let mut findings = 0usize;
        for p in &lint_platforms {
            findings += lint_model(&lint_decorated, &lint_fused, Some(p), &LintConfig::default())
                .diagnostics
                .len();
        }
        findings
    });
    let lint_rate = lint_platforms.len() as f64 / lint_bench.median.as_secs_f64();

    let screen_space = SearchSpace {
        bits: vec![8],
        impls: vec![BlockImpl::Im2col],
        n_blocks: 10,
        cores: vec![1, 8],
        l2_kb: vec![256],
        backends: BackendKind::all().to_vec(),
    };
    let screen_cfg = EvoConfig {
        population: 12,
        generations: 3,
        seed: 29,
        max_evals: 60,
        ..EvoConfig::default()
    };
    let screen_engine = EvalEngine::for_mobilenet(case.clone(), presets::gap8());
    let t0 = std::time::Instant::now();
    let screened = evolve(&screen_engine, &screen_space, &screen_cfg).unwrap();
    let screen_secs = t0.elapsed().as_secs_f64();
    let ss = screened.stats;
    let screen_candidates = screened.evaluations + screened.pruned.len();
    let screen_prune_rate = ss.lint_rejected as f64 / screen_candidates.max(1) as f64;
    println!(
        "static lint: {lint_rate:.1} models/sec over {} (hardware, backend) pairs; \
         evo screen rejected {}/{} candidates ({:.1}%) in {screen_secs:.2}s \
         ({} lint computed / {} cached)",
        lint_platforms.len(),
        ss.lint_rejected,
        screen_candidates,
        screen_prune_rate * 100.0,
        ss.lint_computed,
        ss.lint_hits
    );

    if let Ok(path) = std::env::var("BENCH_LINT_JSON_OUT") {
        let doc = Value::obj()
            .with("bench", "lint_screen")
            .with("tiny", tiny)
            .with("width_mult", case.width_mult)
            .with("lint_models_per_sec", lint_rate)
            .with("lint_platforms", lint_platforms.len())
            .with("screen_candidates", screen_candidates)
            .with("screen_lint_rejected", ss.lint_rejected)
            .with("screen_prune_rate", screen_prune_rate)
            .with("screen_lint_computed", ss.lint_computed)
            .with("screen_lint_hits", ss.lint_hits)
            .with("evo_evaluations", screened.evaluations)
            .with("runs", Value::Arr(vec![stats_json(&lint_bench)]));
        std::fs::write(&path, doc.to_string_pretty()).expect("write lint bench json");
        println!("wrote lint screen bench to {path}");
    }

    if let Ok(path) = std::env::var("BENCH_JSON_OUT") {
        let doc = Value::obj()
            .with("bench", "joint_dse")
            .with("tiny", tiny)
            .with("width_mult", case.width_mult)
            .with("grid_points", grid_points.len())
            .with("sequential_cand_per_sec", seq_rate)
            .with("engine_cand_per_sec", eng_rate)
            .with("speedup", eng_rate / seq_rate)
            .with(
                "runs",
                Value::Arr(vec![
                    stats_json(&seq),
                    stats_json(&eng),
                    stats_json(&joint_bench),
                ]),
            )
            .with("joint_candidates", joint.records.len())
            .with("joint_front", joint.front.len())
            .with("cache_stats", js.to_json());
        std::fs::write(&path, doc.to_string_pretty()).expect("write bench json");
        println!("wrote bench timings to {path}");
    }
}
