//! Bench: the unified joint quantization × hardware DSE engine.
//!
//! Measures (a) the Fig. 7 hardware grid evaluated the old way — one full
//! parse→decorate→fuse→tile→simulate pipeline per candidate, sequentially —
//! against the cache-backed parallel engine, in candidates/sec; and (b) the
//! joint quant×hardware product space (`aladin dse --joint`) where the
//! cache collapses the per-quant-config decoration across every hardware
//! point. Also prints the stage-recomputation accounting that the
//! `engine_cache` integration test asserts.
//!
//! CI smoke mode: `BENCH_TINY=1` shrinks the workload (width-mult 0.25) so
//! the bench runs in seconds, and `BENCH_JSON_OUT=<path>` writes the
//! timings + cache counters as a JSON artifact (`BENCH_joint_dse.json`) so
//! the per-PR perf trajectory accumulates.

use aladin::coordinator::Pipeline;
use aladin::dse::{explore_joint, EvalEngine, GridSearch, JointSpace};
use aladin::impl_aware::decorate;
use aladin::models;
use aladin::platform::presets;
use aladin::util::bench::{bench, BenchStats};
use aladin::util::json::Value;
use aladin::util::ToJson;

fn stats_json(s: &BenchStats) -> Value {
    Value::obj()
        .with("name", s.name.clone())
        .with("iters", s.iters)
        .with("min_us", s.min.as_micros() as u64)
        .with("median_us", s.median.as_micros() as u64)
        .with("mean_us", s.mean.as_micros() as u64)
        .with("max_us", s.max.as_micros() as u64)
}

fn main() {
    let tiny = std::env::var("BENCH_TINY").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    println!(
        "=== joint DSE: sequential pipeline vs cache-backed engine (Case 2{}) ===",
        if tiny { ", tiny grid" } else { "" }
    );

    let mut case = models::case2();
    if tiny {
        case.width_mult = 0.25;
    }
    let (g, cfg) = case.build();
    let grid_points: Vec<(usize, u64)> = [2usize, 4, 8]
        .iter()
        .flat_map(|&c| [256u64, 320, 512].iter().map(move |&l2| (c, l2)))
        .collect();

    // (a) sequential baseline: the pre-engine behaviour — every candidate
    // re-runs the whole pipeline from the canonical graph
    let seq = bench("joint_dse/fig7_9pts/sequential_pipeline", 1, 5, || {
        let mut total = 0u64;
        for &(c, l2) in &grid_points {
            let a = Pipeline::new(presets::gap8_with(c, l2), cfg.clone())
                .analyze(g.clone())
                .unwrap();
            total += a.latency.total_cycles;
        }
        total
    });

    // (b) the engine: stage-1 shared, stage-2 parallel across the grid
    let eng = bench("joint_dse/fig7_9pts/eval_engine", 1, 5, || {
        GridSearch::fig7(presets::gap8())
            .run_canonical(g.clone(), &cfg)
            .unwrap()
            .len()
    });

    let n = grid_points.len() as f64;
    let seq_rate = n / seq.median.as_secs_f64();
    let eng_rate = n / eng.median.as_secs_f64();
    println!(
        "\nFig. 7 grid throughput: sequential {seq_rate:.2} candidates/sec, \
         engine {eng_rate:.2} candidates/sec ({:.2}x)",
        eng_rate / seq_rate
    );

    // recomputation accounting on a persistent engine
    let decorated = decorate(g.clone(), &cfg).unwrap();
    let engine = EvalEngine::for_decorated(decorated, presets::gap8());
    let pts = GridSearch::fig7(presets::gap8()).run_on(&engine).unwrap();
    let s = engine.stats();
    println!(
        "Fig. 7 grid recomputation: {} pipeline-stage computations for {} \
         candidates x 2 stages ({} uncached) — stage-1 {}x, stage-2 {}x",
        s.recomputations(),
        pts.len(),
        s.naive_recomputations(),
        s.impl_computed,
        s.sim_computed
    );
    assert!(
        s.recomputations() < pts.len() * 2,
        "cache must beat point-count x stage-count"
    );

    // (c) the joint quant x hardware product space: 2 quant configs x 9
    // hardware points; each quant config is decorated exactly once
    let space = JointSpace::default_grid();
    let case_for_joint = case.clone();
    let joint_bench = bench("joint_dse/joint_18cand/case2", 1, 3, || {
        explore_joint(case_for_joint.clone(), presets::gap8(), &space, None)
            .unwrap()
            .records
            .len()
    });
    let joint = explore_joint(case.clone(), presets::gap8(), &space, None).unwrap();
    let js = joint.stats;
    println!(
        "joint space: {} candidates, Pareto front {} — {} stage computations \
         ({} uncached): stage-1 {}x for {} quant configs, stage-2 {}x",
        joint.records.len(),
        joint.front.len(),
        js.recomputations(),
        js.naive_recomputations(),
        js.impl_computed,
        space.quant_axes(10).len(),
        js.sim_computed
    );

    if let Ok(path) = std::env::var("BENCH_JSON_OUT") {
        let doc = Value::obj()
            .with("bench", "joint_dse")
            .with("tiny", tiny)
            .with("width_mult", case.width_mult)
            .with("grid_points", grid_points.len())
            .with("sequential_cand_per_sec", seq_rate)
            .with("engine_cand_per_sec", eng_rate)
            .with("speedup", eng_rate / seq_rate)
            .with(
                "runs",
                Value::Arr(vec![
                    stats_json(&seq),
                    stats_json(&eng),
                    stats_json(&joint_bench),
                ]),
            )
            .with("joint_candidates", joint.records.len())
            .with("joint_front", joint.front.len())
            .with("cache_stats", js.to_json());
        std::fs::write(&path, doc.to_string_pretty()).expect("write bench json");
        println!("wrote bench timings to {path}");
    }
}
