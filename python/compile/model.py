"""L2: MobileNetV1 (CIFAR-scale) in JAX — float training forward and the
mixed-precision integer inference forward that calls the L1 Pallas kernels.

Mirrors `rust/src/models/mobilenet.rs`: pilot conv + 10 depthwise-separable
blocks + global average pooling + FC classifier (paper Table I). The
quantized forward is integer end-to-end: activations/weights at the
per-block precision, int32 accumulators, dyadic requantization — with the
pointwise/FC matmuls routed through `kernels.qmatmul` (im2col) or
`kernels.lut_matmul` (LUT blocks), exactly the implementation choices the
rust analysis pipeline models.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import lut_matmul as lut_mod
from .kernels import qmatmul as qm_mod
from .kernels import ref as kref

# (pointwise out-channels, depthwise stride) per block — same plan as
# rust/src/models/mobilenet.rs::BLOCK_PLAN.
BLOCK_PLAN = [
    (64, 1), (128, 2), (128, 1), (256, 2), (256, 1),
    (512, 2), (512, 1), (512, 1), (1024, 2), (1024, 1),
]
PILOT_CHANNELS = 32
NUM_CLASSES = 10


@dataclass
class CaseConfig:
    """One Table-I column: per-block (bits, impl) plus pilot/classifier."""

    name: str
    pilot_bits: int = 8
    block_bits: list = field(default_factory=lambda: [8] * 10)
    block_impl: list = field(default_factory=lambda: ["im2col"] * 10)
    classifier_bits: int = 8
    classifier_impl: str = "im2col"
    width_mult: float = 0.25


def case1(width: float = 0.25) -> CaseConfig:
    return CaseConfig(name="case1", width_mult=width)


def case2(width: float = 0.25) -> CaseConfig:
    return CaseConfig(
        name="case2",
        block_bits=[4] * 10,
        block_impl=["im2col"] * 7 + ["lut"] * 3,
        width_mult=width,
    )


def case3(width: float = 0.25) -> CaseConfig:
    return CaseConfig(
        name="case3",
        block_bits=[8, 4, 4, 4, 4, 4, 4, 4, 4, 2],
        block_impl=["im2col"] * 5 + ["lut"] * 5,
        classifier_bits=4,
        classifier_impl="lut",
        width_mult=width,
    )


ALL_CASES = {"case1": case1, "case2": case2, "case3": case3}


def _ch(c: int, width: float) -> int:
    return max(8, int(round(c * width)))


def channel_plan(width: float):
    """(pilot_channels, [(block_out_channels, stride)])."""
    pilot = _ch(PILOT_CHANNELS, width)
    blocks = [(_ch(c, width), s) for c, s in BLOCK_PLAN]
    return pilot, blocks


# --------------------------------------------------------------------------
# float model (training path)
# --------------------------------------------------------------------------


def init_params(seed: int = 0, width: float = 0.25) -> dict:
    """He-init float parameters. Layout:
    conv kernels [kh, kw, cin, cout] (depthwise: [kh, kw, c, 1]),
    biases [cout], fc weight [k, classes]."""
    rng = np.random.default_rng(seed)
    pilot, blocks = channel_plan(width)

    def conv(kh, kw, cin, cout):
        fan_in = kh * kw * cin
        return rng.normal(scale=math.sqrt(2.0 / fan_in), size=(kh, kw, cin, cout)).astype(
            np.float32
        )

    params = {
        "pilot/w": conv(3, 3, 3, pilot),
        "pilot/b": np.zeros(pilot, np.float32),
    }
    cin = pilot
    for i, (cout, _stride) in enumerate(blocks, start=1):
        # HWIO depthwise layout: [3, 3, 1, C] (in-features per group = 1)
        params[f"dw{i}/w"] = conv(3, 3, 1, cin)
        params[f"dw{i}/b"] = np.zeros(cin, np.float32)
        params[f"pw{i}/w"] = conv(1, 1, cin, cout)
        params[f"pw{i}/b"] = np.zeros(cout, np.float32)
        cin = cout
    params["fc/w"] = rng.normal(scale=math.sqrt(1.0 / cin), size=(cin, NUM_CLASSES)).astype(
        np.float32
    )
    params["fc/b"] = np.zeros(NUM_CLASSES, np.float32)
    return {k: jnp.asarray(v) for k, v in params.items()}


def _conv(x, w, stride, groups=1):
    # Explicit symmetric (1,1) padding for 3x3 kernels — NOT lax "SAME",
    # whose stride-2 padding is asymmetric (0,1) and would misalign the
    # integer im2col path used by the quantized forward.
    pad = (1, 1) if w.shape[0] > 1 else (0, 0)
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[pad, pad],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def float_forward(params, x, width: float = 0.25, collect=None):
    """Float inference. `collect`, if a dict, receives pre-quant activation
    tensors per layer name (for PTQ calibration)."""
    _, blocks = channel_plan(width)

    def note(name, h):
        if collect is not None:
            collect[name] = h
        return h

    h = jax.nn.relu(_conv(x, params["pilot/w"], 1) + params["pilot/b"])
    h = note("pilot", h)
    for i, (_cout, stride) in enumerate(blocks, start=1):
        c = h.shape[-1]
        h = jax.nn.relu(_conv(h, params[f"dw{i}/w"], stride, groups=c) + params[f"dw{i}/b"])
        h = note(f"dw{i}", h)
        h = jax.nn.relu(_conv(h, params[f"pw{i}/w"], 1) + params[f"pw{i}/b"])
        h = note(f"pw{i}", h)
    h = h.mean(axis=(1, 2))  # global average pooling
    h = note("pool", h)
    return h @ params["fc/w"] + params["fc/b"]


# --------------------------------------------------------------------------
# quantization (PTQ) + integer inference
# --------------------------------------------------------------------------


def _qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1


def _dyadic(scale: float, max_n: int = 31):
    """Fit M/2^n ≈ scale (paper §VI-C) — same algorithm as
    rust/src/quant/dyadic.rs."""
    n = max_n
    while n > 1:
        m = round(scale * (1 << n))
        if m <= 0x7FFFFFFF:
            return max(1, int(m)), n
        n -= 1
    return max(1, int(round(scale * 2))), 1


def _quantize_tensor(w, bits: int):
    """Symmetric per-tensor weight quantization. Returns (w_q int32, scale)."""
    s = float(np.max(np.abs(np.asarray(w)))) / _qmax(bits)
    s = max(s, 1e-12)
    w_q = np.clip(np.round(np.asarray(w) / s), -_qmax(bits) - 1, _qmax(bits)).astype(np.int32)
    return w_q, s


def _quantize_perchannel(w, bits: int):
    """Symmetric per-output-channel ("filter-wise", paper §II-A) weight
    quantization over the last axis. Returns (w_q int32, scales [Cout])."""
    arr = np.asarray(w)
    flat = arr.reshape(-1, arr.shape[-1])
    s = np.abs(flat).max(axis=0) / _qmax(bits)
    s = np.maximum(s, 1e-12)
    w_q = np.clip(np.round(arr / s), -_qmax(bits) - 1, _qmax(bits)).astype(np.int32)
    return w_q, s


def calibrate(params, x_calib, width: float = 0.25) -> dict:
    """Per-layer post-ReLU activation max (PTQ calibration stats)."""
    acts: dict = {}
    float_forward(params, x_calib, width=width, collect=acts)
    stats = {k: float(jnp.max(jnp.abs(v))) for k, v in acts.items()}
    stats["input"] = float(jnp.max(jnp.abs(x_calib)))
    return stats


def quantize_model(params, stats: dict, cfg: CaseConfig) -> dict:
    """Build the integer parameter set for one Table-I case.

    Per layer: w_q (int), bias_q (int32, scale s_x*s_w), dyadic (M, n)
    realizing s_x*s_w/s_y, and the activation clip range of the output."""
    width = cfg.width_mult
    _, blocks = channel_plan(width)
    q: dict = {"cfg": cfg}

    def act_scale(name: str, bits: int) -> float:
        return max(stats[name], 1e-12) / _qmax(bits)

    # activation precision entering each layer: pilot sees int8 input
    s_in = act_scale("input", 8)
    q["input_scale"] = s_in

    # Shared shift for the per-channel dyadic multipliers: M_c = r_c * 2^n
    # with r_c = s_x * s_w_c / s_y (filter-wise quantization, paper §II-A).
    SHIFT = 22

    def prep(layer: str, w_key: str, b_key: str, w_bits: int, s_x: float,
             out_name: str, out_bits: int):
        w_q, s_w = _quantize_perchannel(params[w_key], w_bits)
        s_y = act_scale(out_name, out_bits)
        bias_q = np.round(np.asarray(params[b_key]) / (s_x * s_w)).astype(np.int32)
        r = s_x * s_w / s_y  # [Cout]
        m = np.maximum(1, np.round(r * (1 << SHIFT))).astype(np.int64)
        assert m.max() < 2**31, f"{layer}: dyadic multiplier overflow"
        q[layer] = {
            "w_q": jnp.asarray(w_q),
            "bias_q": jnp.asarray(bias_q),
            "m": jnp.asarray(m, dtype=jnp.int32),
            "n": SHIFT,
            "out_hi": _qmax(out_bits),
            "s_y": s_y,
        }
        return s_y

    s_x = prep("pilot", "pilot/w", "pilot/b", cfg.pilot_bits, s_in, "pilot", cfg.pilot_bits)
    for i in range(1, 11):
        bits = cfg.block_bits[i - 1]
        s_x = prep(f"dw{i}", f"dw{i}/w", f"dw{i}/b", bits, s_x, f"dw{i}", bits)
        s_x = prep(f"pw{i}", f"pw{i}/w", f"pw{i}/b", bits, s_x, f"pw{i}", bits)
    # classifier: per-tensor (per-class scales would distort the argmax);
    # logits stay at int32 accumulator scale (dequantized after)
    w_q, s_w = _quantize_tensor(params["fc/w"], cfg.classifier_bits)
    bias_q = np.round(np.asarray(params["fc/b"]) / (s_x * s_w)).astype(np.int32)
    q["fc"] = {
        "w_q": jnp.asarray(w_q),
        "bias_q": jnp.asarray(bias_q),
        "s_out": s_x * s_w,
        "s_x": s_x,
    }
    if cfg.classifier_impl == "lut" or "lut" in cfg.block_impl:
        pass  # LUTs are built lazily in quantized_forward (static shapes)
    return q


def _im2col(x, kh: int, kw: int, stride: int, pad: int):
    """Integer im2col: x [B,H,W,C] -> patches [B*OH*OW, kh*kw*C] with
    k-index order (kh, kw, c) matching `w.reshape(kh*kw*cin, cout)`."""
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    b, h, w_, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w_ - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :])
    patches = jnp.stack(cols, axis=3)  # [B, OH, OW, kh*kw, C]
    return patches.reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def _dw_conv_int(x_q, w_q, stride: int):
    """Integer depthwise 3x3 conv: x [B,H,W,C] int32, w [3,3,C,1] int32."""
    patches, (b, oh, ow) = _im2col(x_q, 3, 3, stride, 1)
    c = x_q.shape[-1]
    patches = patches.reshape(b * oh * ow, 9, c)
    w = w_q.reshape(9, c)
    acc = jnp.einsum("mkc,kc->mc", patches, w, preferred_element_type=jnp.int32)
    return acc.reshape(b, oh, ow, c)


def _linear_int(x2d, layer, impl: str, relu: bool, w_bits: int, x_bits: int):
    """Dispatch a quantized matmul to the configured L1 kernel."""
    w_q, bias_q = layer["w_q"], layer["bias_q"]
    lo = 0 if relu else -layer["out_hi"] - 1
    hi = layer["out_hi"]
    if impl == "lut":
        lut, x_levels, x_lo, w_lo = kref.build_mul_lut(w_bits, x_bits)
        return lut_mod.lut_matmul(
            x2d, w_q, lut, x_levels, x_lo, w_lo, bias_q, layer["m"], layer["n"], lo, hi
        )
    return qm_mod.qmatmul(x2d, w_q, bias_q, layer["m"], layer["n"], lo, hi)


def quantized_forward(q: dict, x):
    """Integer inference of one Table-I case. `x` is float [B,32,32,3];
    returns float logits [B, 10] (dequantized classifier accumulators)."""
    cfg: CaseConfig = q["cfg"]
    width = cfg.width_mult
    _, blocks = channel_plan(width)

    # input quantization (int8, symmetric)
    x_q = jnp.clip(jnp.round(x / q["input_scale"]), -128, 127).astype(jnp.int32)

    # pilot: standard 3x3 conv via im2col + Pallas qmatmul (always im2col)
    layer = q["pilot"]
    patches, (b, oh, ow) = _im2col(x_q, 3, 3, 1, 1)
    w2d = layer["w_q"].reshape(-1, layer["w_q"].shape[-1])
    h = _linear_int(patches, {**layer, "w_q": w2d}, "im2col", True, cfg.pilot_bits, 8)
    h = h.reshape(b, oh, ow, -1)
    x_bits = cfg.pilot_bits

    for i, (_cout, stride) in enumerate(blocks, start=1):
        bits = cfg.block_bits[i - 1]
        impl = cfg.block_impl[i - 1]
        # depthwise 3x3 (integer direct conv) + fused relu/requant
        dw = q[f"dw{i}"]
        acc = _dw_conv_int(h, dw["w_q"], stride) + dw["bias_q"][None, None, None, :]
        h = kref.dyadic_requant_ref(acc, dw["m"], dw["n"], 0, dw["out_hi"])
        # pointwise 1x1 through the configured kernel
        pw = q[f"pw{i}"]
        b_, oh_, ow_, c = h.shape
        x2d = h.reshape(b_ * oh_ * ow_, c)
        w2d = pw["w_q"].reshape(c, -1)
        h = _linear_int(x2d, {**pw, "w_q": w2d}, impl, True, bits, bits)
        h = h.reshape(b_, oh_, ow_, -1)
        x_bits = bits

    # global average pooling in the integer domain (shift-free mean; the
    # platform uses a power-of-two shift — here spatial is 2x2 = exact)
    h = h.sum(axis=(1, 2)) // (h.shape[1] * h.shape[2])

    # classifier: integer matmul (MAC or LUT gather), logits dequantized
    fc = q["fc"]
    h = h.astype(jnp.int32)
    if cfg.classifier_impl == "lut":
        # partial products from the pre-computed table (paper §II-B)
        lut, x_levels, x_lo, w_lo = kref.build_mul_lut(cfg.classifier_bits, x_bits)
        xi = h - x_lo                                     # [B, K]
        wi = fc["w_q"].astype(jnp.int32) - w_lo           # [K, 10]
        idx = wi.T[None, :, :] * x_levels + xi[:, None, :]
        acc = lut[idx].sum(axis=-1).astype(jnp.int32) + fc["bias_q"][None, :]
    else:
        acc = h @ fc["w_q"].astype(jnp.int32) + fc["bias_q"][None, :]
    return acc.astype(jnp.float32) * fc["s_out"]
