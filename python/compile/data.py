"""Synthetic CIFAR-10-shaped dataset (DESIGN.md substitution: real CIFAR-10
is not available offline; a 10-class separable-but-noisy image distribution
exercises the identical quantized inference code path).

Each class has a smooth random "prototype" 32x32x3 image (low-frequency
random field); samples are prototype + structured noise. Difficulty is
tuned via the noise level so that quantization-induced accuracy loss is
visible (int8 > int4 > int2 ordering, as in Table I).
"""

from __future__ import annotations

import numpy as np

IMAGE_SHAPE = (32, 32, 3)
NUM_CLASSES = 10


def _smooth_field(rng: np.random.Generator, shape, cutoff: int = 6) -> np.ndarray:
    """Low-frequency random field via truncated 2D Fourier synthesis."""
    h, w, c = shape
    field = np.zeros(shape, dtype=np.float64)
    for ch in range(c):
        coeff = np.zeros((h, w), dtype=np.complex128)
        coeff[:cutoff, :cutoff] = rng.normal(size=(cutoff, cutoff)) + 1j * rng.normal(
            size=(cutoff, cutoff)
        )
        img = np.fft.ifft2(coeff).real
        img = (img - img.mean()) / (img.std() + 1e-9)
        field[..., ch] = img
    return field.astype(np.float32)


def class_prototypes(seed: int = 1234) -> np.ndarray:
    """[NUM_CLASSES, 32, 32, 3] smooth prototypes, deterministic."""
    rng = np.random.default_rng(seed)
    return np.stack([_smooth_field(rng, IMAGE_SHAPE) for _ in range(NUM_CLASSES)])


def make_split(
    n: int, seed: int, noise: float = 3.0, proto_seed: int = 1234
) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` examples: returns (images [n,32,32,3] f32 in ~[-3,3],
    labels [n] int32). Noise mixes white noise and a smooth distractor
    field so the task needs more than average color."""
    protos = class_prototypes(proto_seed)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    images = np.empty((n,) + IMAGE_SHAPE, dtype=np.float32)
    for i, y in enumerate(labels):
        white = rng.normal(scale=noise, size=IMAGE_SHAPE).astype(np.float32)
        smooth = _smooth_field(rng, IMAGE_SHAPE) * (noise * 0.5)
        images[i] = protos[y] + white + smooth
    return images, labels


def train_test(
    n_train: int = 4096, n_test: int = 1024, noise: float = 3.0
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """The canonical train/test split used by train.py and aot.py."""
    xtr, ytr = make_split(n_train, seed=7, noise=noise)
    xte, yte = make_split(n_test, seed=1007, noise=noise)
    return xtr, ytr, xte, yte
