"""AOT export: train (cached) -> PTQ-quantize per Table-I case -> lower the
integer inference graph (with its Pallas kernels, interpret=True) to HLO
*text* -> write artifacts/ for the rust runtime.

HLO text, not `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).

Usage: python -m compile.aot [--out-dir ../artifacts] [--batch 64]
       [--steps 400] [--cases case1,case2,case3]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)  # int64 dyadic requant path

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default HLO printer elides big
    # weight tensors as `{...}`, which the text parser on the rust side
    # would reject/zero — the artifact must be self-contained.
    return comp.as_hlo_text(print_large_constants=True)


def export_case(q: dict, batch: int, out_path: Path) -> dict:
    """Lower one quantized model to HLO text; returns its manifest entry."""
    cfg = q["cfg"]

    def fn(x):
        return (model.quantized_forward(q, x),)

    spec = jax.ShapeDtypeStruct((batch,) + data.IMAGE_SHAPE, jnp.float32)
    t0 = time.time()
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    out_path.write_text(text)
    print(f"  {cfg.name}: wrote {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s",
          flush=True)
    return {
        "name": cfg.name,
        "hlo": out_path.name,
        "input_shape": [batch, *data.IMAGE_SHAPE],
        "output_shape": [batch, data.NUM_CLASSES],
    }


def export_testset(xte: np.ndarray, yte: np.ndarray, out_dir: Path) -> None:
    bin_path = out_dir / "testset.bin"
    bin_path.write_bytes(np.ascontiguousarray(xte, dtype="<f4").tobytes())
    header = {
        "n": int(xte.shape[0]),
        "image_shape": list(xte.shape[1:]),
        "images_bin": "testset.bin",
        "labels": [int(v) for v in yte],
    }
    (out_dir / "testset.json").write_text(json.dumps(header))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=str(Path(__file__).parents[2] / "artifacts"))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--n-test", type=int, default=512)
    ap.add_argument("--cases", default="case1,case2,case3")
    ap.add_argument("--sanity", action="store_true",
                    help="also report python-side quantized accuracy")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    params, float_acc = train.load_or_train(width=args.width, steps=args.steps)

    _, _, xte, yte = data.train_test()
    xte, yte = xte[: args.n_test], yte[: args.n_test]
    xtr, _, _, _ = data.train_test(n_train=256, n_test=1)
    stats = model.calibrate(params, jnp.asarray(xtr[:256]), width=args.width)

    export_testset(xte, yte, out_dir)

    entries = []
    for name in args.cases.split(","):
        cfg = model.ALL_CASES[name.strip()](width=args.width)
        q = model.quantize_model(params, stats, cfg)
        entries.append(export_case(q, args.batch, out_dir / f"{cfg.name}.hlo.txt"))
        if args.sanity:
            logits = model.quantized_forward(q, jnp.asarray(xte[:128]))
            acc = float(jnp.mean(jnp.argmax(logits, 1) == jnp.asarray(yte[:128])))
            print(f"  {cfg.name}: python-side quantized acc (128 ex) = {acc:.4f}",
                  flush=True)

    manifest = {"models": entries, "testset": "testset.json"}
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"manifest with {len(entries)} models -> {out_dir / 'manifest.json'}")
    print(f"(float reference accuracy: {float_acc:.4f})")


if __name__ == "__main__":
    main()
