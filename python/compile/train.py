"""Build-time training of the float MobileNetV1 on the synthetic dataset
(the DESIGN.md substitution for the paper's Brevitas QAT on CIFAR-10).

Pure-JAX SGD with momentum + cosine decay; weights are cached in
`python/compile/_cache/weights.npz` so `make artifacts` re-runs are fast.
"""

from __future__ import annotations

import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model

CACHE = Path(__file__).parent / "_cache"


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels) -> float:
    return float(jnp.mean(jnp.argmax(logits, axis=1) == labels))


def train(
    width: float = 0.25,
    steps: int = 400,
    batch: int = 128,
    lr: float = 2e-3,
    weight_decay: float = 1e-5,
    seed: int = 0,
    verbose: bool = True,
):
    """Train with Adam (hand-rolled — no optax in the offline image) and
    return (params, test_accuracy)."""
    xtr, ytr, xte, yte = data.train_test()
    xtr, ytr = jnp.asarray(xtr), jnp.asarray(ytr)
    params = model.init_params(seed=seed, width=width)
    m_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    v_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    b1, b2, eps = 0.9, 0.999, 1e-8

    def loss_fn(p, xb, yb):
        logits = model.float_forward(p, xb, width=width)
        wd = sum(jnp.sum(v * v) for k, v in p.items() if k.endswith("/w"))
        return cross_entropy(logits, yb) + weight_decay * wd

    @jax.jit
    def step(p, m, v, xb, yb, lr_t, t):
        lr_t = lr_t.astype(jnp.float32)  # keep params f32 under x64 mode
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        m = {k: b1 * m[k] + (1 - b1) * grads[k] for k in p}
        v = {k: b2 * v[k] + (1 - b2) * grads[k] ** 2 for k in p}
        tf = t.astype(jnp.float32) + 1.0
        mhat = {k: m[k] / (1 - b1 ** tf) for k in p}
        vhat = {k: v[k] / (1 - b2 ** tf) for k in p}
        p = {k: p[k] - lr_t * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in p}
        return p, m, v, loss

    rng = np.random.default_rng(seed)
    n = xtr.shape[0]
    for t in range(steps):
        idx = rng.integers(0, n, size=batch)
        lr_t = lr * 0.5 * (1 + np.cos(np.pi * t / steps))
        params, m_state, v_state, loss = step(
            params, m_state, v_state, xtr[idx], ytr[idx],
            jnp.asarray(lr_t), jnp.asarray(t),
        )
        if verbose and (t % 50 == 0 or t == steps - 1):
            print(f"step {t:4d}  loss {float(loss):.4f}  lr {lr_t:.4f}", flush=True)

    logits = model.float_forward(params, jnp.asarray(xte), width=width)
    acc = accuracy(logits, jnp.asarray(yte))
    if verbose:
        print(f"float test accuracy: {acc:.4f}", flush=True)
    return params, acc


def load_or_train(width: float = 0.25, steps: int = 400, verbose: bool = True):
    """Cached training: reuse `_cache/weights.npz` when present."""
    CACHE.mkdir(exist_ok=True)
    path = CACHE / f"weights_w{width}_s{steps}.npz"
    if path.exists():
        blob = np.load(path)
        params = {k: jnp.asarray(blob[k]) for k in blob.files if k != "__acc"}
        acc = float(blob["__acc"]) if "__acc" in blob.files else -1.0
        if verbose:
            print(f"loaded cached weights from {path} (float acc {acc:.4f})", flush=True)
        return params, acc
    params, acc = train(width=width, steps=steps, verbose=verbose)
    np.savez(path, __acc=np.float64(acc), **{k: np.asarray(v) for k, v in params.items()})
    return params, acc


if __name__ == "__main__":
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    load_or_train()
