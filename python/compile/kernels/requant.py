"""L1 Pallas kernel: threshold-tree requantization (paper §VI-C).

Maps accumulator values to `2^Ly` output levels by counting how many of the
`T = 2^Ly - 1` ascending thresholds each value passes — the vectorized
equivalent of the balanced comparator tree (`O(log T)` depth in hardware;
a data-parallel compare-and-sum here).

interpret=True (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _threshold_kernel(acc_ref, thr_ref, o_ref, *, lo):
    acc = acc_ref[...]
    thr = thr_ref[...]
    cmp = acc[:, None] >= thr[None, :]
    o_ref[...] = (lo + cmp.sum(axis=-1)).astype(jnp.int32)


def threshold_requant(acc, thresholds, lo: int):
    """Requantize a flat int32 array through ascending `thresholds`.

    Returns int32 levels in [lo, lo + T]. Bit-exact vs
    `ref.threshold_requant_ref`.
    """
    (n,) = acc.shape
    (t,) = thresholds.shape
    pad = (-n) % BLOCK
    if pad:
        acc = jnp.pad(acc, (0, pad))
    padded = n + pad

    kernel = functools.partial(_threshold_kernel, lo=lo)
    out = pl.pallas_call(
        kernel,
        grid=(padded // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.int32),
        interpret=True,
    )(acc.astype(jnp.int32), thresholds.astype(jnp.int32))
    return out[:n]
