"""L1 Pallas kernels (interpret=True) + their pure-jnp oracles."""

from . import lut_matmul, qmatmul, ref, requant  # noqa: F401
