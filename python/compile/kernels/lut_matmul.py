"""L1 Pallas kernel: LUT-based quantized matmul (paper §II-B, §VI-A).

Partial products come from a pre-computed `2^(Lw+La)`-entry table instead
of multiplier hardware: a MAC becomes a table gather + accumulate. On the
paper's platform the table lives in the shared L1 scratchpad; here the
table lives in VMEM next to each block (the TPU analogue — DESIGN.md §6),
and the gather exercises the same trade of multiplier work for memory.

interpret=True (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Smaller M-tile than qmatmul: the [BLOCK_M, N, K] gather intermediate is
# the VMEM limiter for the LUT path.
BLOCK_M = 32


def _lut_kernel(lut_ref, x_ref, w_ref, b_ref, m_ref, o_ref, *,
                x_levels, x_lo, w_lo, shift, lo, hi):
    """One M-tile: gather partial products from the LUT and accumulate."""
    lut = lut_ref[...]
    xi = x_ref[...].astype(jnp.int32) - x_lo          # [bm, K]
    wi = w_ref[...].astype(jnp.int32) - w_lo          # [K, N]
    # index of (w, x) in the flattened table
    idx = wi.T[None, :, :] * x_levels + xi[:, None, :]  # [bm, N, K]
    prods = jnp.take(lut, idx, axis=0)
    acc = prods.sum(axis=-1).astype(jnp.int32) + b_ref[...][None, :]
    prod = acc.astype(jnp.int64) * m_ref[...][None, :].astype(jnp.int64)
    out = (prod + (jnp.int64(1) << (shift - 1))) >> shift
    o_ref[...] = jnp.clip(out, lo, hi).astype(jnp.int32)


def lut_matmul(x_q, w_q, lut, x_levels: int, x_lo: int, w_lo: int,
               bias_q, m_mult, shift: int, lo: int, hi: int):
    """LUT-based [M, K] @ [K, N] -> [M, N] int32 in [lo, hi].

    `lut` is the flat `[w_levels * x_levels]` int32 product table from
    `ref.build_mul_lut`. Bit-exact vs `ref.lut_matmul_ref` (and therefore
    vs `qmatmul` when the LUT encodes exact products).
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    m_vec = jnp.broadcast_to(jnp.asarray(m_mult, dtype=jnp.int32), (n,))
    pad = (-m) % BLOCK_M
    if pad:
        x_q = jnp.pad(x_q, ((0, pad), (0, 0)))
    padded_m = m + pad
    t = lut.shape[0]

    kernel = functools.partial(
        _lut_kernel,
        x_levels=x_levels, x_lo=x_lo, w_lo=w_lo,
        shift=shift, lo=lo, hi=hi,
    )
    out = pl.pallas_call(
        kernel,
        grid=(padded_m // BLOCK_M,),
        in_specs=[
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_m, n), jnp.int32),
        interpret=True,
    )(lut.astype(jnp.int32), x_q.astype(jnp.int32), w_q.astype(jnp.int32),
      bias_q.astype(jnp.int32), m_vec)
    return out[:m]
