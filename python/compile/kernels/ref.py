"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its reference here bit-exactly on
integer outputs (pytest + hypothesis enforce it).
"""

from __future__ import annotations

import jax.numpy as jnp


def dyadic_requant_ref(acc, m_mult, shift: int, lo: int, hi: int):
    """Integer dyadic rescale (paper §VI-C): round-to-nearest
    `(acc * M + 2^(n-1)) >> n`, clipped into [lo, hi].

    acc: int32 array. `m_mult` is a scalar (per-tensor) or an array
    broadcastable against `acc` (per-channel / filter-wise quantization,
    §II-A). Returns int32.
    """
    m = jnp.asarray(m_mult, dtype=jnp.int64)
    prod = acc.astype(jnp.int64) * m
    biased = prod + (jnp.int64(1) << (shift - 1))
    out = biased >> shift
    return jnp.clip(out, lo, hi).astype(jnp.int32)


def qmatmul_ref(x_q, w_q, bias_q, m_mult, shift: int, lo: int, hi: int):
    """Quantized matmul + bias + dyadic requant.

    x_q: [M, K] int32 (values within the activation bit range)
    w_q: [K, N] int32 (values within the weight bit range)
    bias_q: [N] int32; m_mult scalar or [N] (per-channel)
    Returns [M, N] int32 in [lo, hi].
    """
    acc = x_q.astype(jnp.int32) @ w_q.astype(jnp.int32) + bias_q[None, :]
    return dyadic_requant_ref(acc, m_mult, shift, lo, hi)


def lut_matmul_ref(x_q, w_q, lut, x_levels: int, x_lo: int, w_lo: int,
                   bias_q, m_mult, shift: int, lo: int, hi: int):
    """LUT-based matmul (paper §II-B): partial products come from a
    pre-computed table indexed by (weight, activation) instead of a MAC.

    lut: [w_levels * x_levels] int32 flattened table with
         lut[(w - w_lo) * x_levels + (x - x_lo)] == w * x.
    Must equal qmatmul_ref numerically when the LUT encodes products.
    """
    xi = (x_q - x_lo).astype(jnp.int32)          # [M, K]
    wi = (w_q - w_lo).astype(jnp.int32)          # [K, N]
    idx = wi.T[None, :, :] * x_levels + xi[:, None, :]   # [M, N, K]
    prods = lut[idx]                              # gather
    acc = prods.sum(axis=-1).astype(jnp.int32) + bias_q[None, :]
    return dyadic_requant_ref(acc, m_mult, shift, lo, hi)


def threshold_requant_ref(acc, thresholds, lo: int):
    """Threshold-tree requantization (paper §VI-C / Eq. 8-9 structure):
    output level = lo + #{i : acc >= thr_i}, thresholds ascending."""
    cmp = acc[..., None] >= thresholds  # [..., T]
    return (lo + cmp.sum(axis=-1)).astype(jnp.int32)


def build_mul_lut(w_bits: int, x_bits: int):
    """Materialize the product table for signed w/x of the given widths.
    Returns (flat_lut int32 [2^(w_bits+x_bits)], x_levels, x_lo, w_lo)."""
    w_lo, w_hi = -(1 << (w_bits - 1)), (1 << (w_bits - 1)) - 1
    x_lo, x_hi = -(1 << (x_bits - 1)), (1 << (x_bits - 1)) - 1
    w_vals = jnp.arange(w_lo, w_hi + 1, dtype=jnp.int32)
    x_vals = jnp.arange(x_lo, x_hi + 1, dtype=jnp.int32)
    lut = (w_vals[:, None] * x_vals[None, :]).reshape(-1)
    return lut, int(x_vals.shape[0]), x_lo, w_lo
