"""L1 Pallas kernel: quantized matmul + bias + dyadic requantization.

The compute hot-spot of the quantized inference path (paper §VI-A: im2col
turns every convolution into exactly this matmul). TPU hardware-adaptation
note (DESIGN.md §6): the kernel tiles the M dimension via BlockSpec — the
VMEM analogue of the L1 tiling Dory performs — accumulates in int32
(MXU-friendly), and fuses the dyadic requantization so accumulators never
round-trip to HBM. interpret=True everywhere: the CPU PJRT client cannot
run Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# M-dimension tile (output pixels per block). 128 keeps the x-block +
# out-block VMEM footprint small (< 0.5 MiB for K,N <= 576) while filling
# the 128-lane dimension of the MXU.
BLOCK_M = 128


def _qmatmul_kernel(x_ref, w_ref, b_ref, m_ref, o_ref, *, shift, lo, hi):
    """One M-tile: int32 matmul + bias + per-channel dyadic requant + clip."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc = jax.lax.dot_general(
        x,
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    acc = acc + b_ref[...][None, :].astype(jnp.int32)
    # dyadic rescale: (acc * M_c + 2^(n-1)) >> n, round-to-nearest;
    # M is per output channel (filter-wise quantization, paper §II-A)
    prod = acc.astype(jnp.int64) * m_ref[...][None, :].astype(jnp.int64)
    out = (prod + (jnp.int64(1) << (shift - 1))) >> shift
    o_ref[...] = jnp.clip(out, lo, hi).astype(jnp.int32)


def qmatmul(x_q, w_q, bias_q, m_mult, shift: int, lo: int, hi: int):
    """Quantized matmul: [M, K] @ [K, N] -> [M, N] int32 in [lo, hi].

    `m_mult` is a scalar (per-tensor) or a [N] vector (per-channel dyadic
    multipliers). Bit-exact vs `ref.qmatmul_ref`. M is padded to a BLOCK_M
    multiple; K and N are kept whole per block (they are small for the
    CIFAR-scale MobileNet: K <= 576, N <= 1024).
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert bias_q.shape == (n,)
    m_vec = jnp.broadcast_to(jnp.asarray(m_mult, dtype=jnp.int32), (n,))

    pad = (-m) % BLOCK_M
    if pad:
        x_q = jnp.pad(x_q, ((0, pad), (0, 0)))
    padded_m = m + pad

    kernel = functools.partial(_qmatmul_kernel, shift=shift, lo=lo, hi=hi)
    out = pl.pallas_call(
        kernel,
        grid=(padded_m // BLOCK_M,),
        in_specs=[
            pl.BlockSpec((BLOCK_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_m, n), jnp.int32),
        interpret=True,
    )(x_q.astype(jnp.int32), w_q.astype(jnp.int32), bias_q.astype(jnp.int32), m_vec)
    return out[:m]
