"""Export the JAX MobileNetV1 case configurations as QONNX-dialect JSON —
the same dialect `rust/src/graph/qonnx.rs` imports. Closes the toolchain
loop: the exact network that is trained/quantized/AOT-compiled in python
can be re-analyzed by the rust pipeline from a file.

Usage: python -m compile.export_qonnx [--out-dir ../artifacts] [--width 0.25]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from . import model


def _tensor(name, dims, bits, signed=True, initializer=False):
    return {
        "name": name,
        "dims": list(dims),
        "bits": int(bits),
        "signed": signed,
        "initializer": initializer,
    }


def export_case(cfg: model.CaseConfig) -> dict:
    """Build the QONNX-dialect document for one Table-I case."""
    pilot_c, blocks = model.channel_plan(cfg.width_mult)
    tensors = [_tensor("x0", (3, 32, 32), 8)]
    nodes = []
    edge = "x0"
    h = w = 32
    counter = [0]

    def fresh(prefix):
        counter[0] += 1
        return f"{prefix}_{counter[0]}"

    def conv(name, cin, cout, k, stride, pad, groups, w_bits, acc_bits, out_bits):
        nonlocal edge, h, w
        wname, bname = f"{name}.weight", f"{name}.bias"
        tensors.append(_tensor(wname, (cout, cin // groups, k, k), w_bits, initializer=True))
        tensors.append(_tensor(bname, (cout,), acc_bits, initializer=True))
        oh = (h + 2 * pad - k) // stride + 1
        ow = (w + 2 * pad - k) // stride + 1
        acc_edge = fresh("acc")
        tensors.append(_tensor(acc_edge, (cout, oh, ow), acc_bits))
        nodes.append({
            "name": name,
            "op_type": "Conv",
            "inputs": [edge, wname, bname],
            "outputs": [acc_edge],
            "attributes": {
                "kernel_shape": [k, k], "strides": [stride, stride],
                "pads": [pad, pad], "group": groups,
            },
        })
        # relu
        r_edge = fresh("r")
        tensors.append(_tensor(r_edge, (cout, oh, ow), acc_bits))
        nodes.append({
            "name": name.replace("Conv", "Relu").replace("Gemm", "Relu"),
            "op_type": "Relu", "inputs": [acc_edge], "outputs": [r_edge],
            "attributes": {},
        })
        # quant
        q_edge = fresh("q")
        tensors.append(_tensor(q_edge, (cout, oh, ow), out_bits))
        nodes.append({
            "name": name.replace("Conv", "Quant"),
            "op_type": "Quant", "inputs": [r_edge], "outputs": [q_edge],
            "attributes": {"bits": out_bits, "signed": True, "channelwise": True},
        })
        edge, h, w = q_edge, oh, ow
        return cout

    def acc_of(bits):
        return 16 if bits < 8 else 32

    cin = conv("Conv_pilot", 3, pilot_c, 3, 1, 1, 1,
               cfg.pilot_bits, acc_of(cfg.pilot_bits), cfg.pilot_bits)
    for i, (cout, stride) in enumerate(blocks, start=1):
        bits = cfg.block_bits[i - 1]
        cin = conv(f"Conv_dw{i}", cin, cin, 3, stride, 1, cin, bits, acc_of(bits), bits)
        cin = conv(f"Conv_pw{i}", cin, cout, 1, 1, 0, 1, bits, acc_of(bits), bits)

    # global average pool + flatten + classifier
    pool_out = fresh("pool")
    tensors.append(_tensor(pool_out, (cin, 1, 1), cfg.block_bits[-1]))
    nodes.append({
        "name": "AvgPool_head", "op_type": "AveragePool",
        "inputs": [edge], "outputs": [pool_out],
        "attributes": {"kernel_shape": [h, w]},
    })
    flat = fresh("flat")
    tensors.append(_tensor(flat, (cin,), cfg.block_bits[-1]))
    nodes.append({
        "name": "Flatten_head", "op_type": "Flatten",
        "inputs": [pool_out], "outputs": [flat], "attributes": {},
    })
    cb = cfg.classifier_bits
    tensors.append(_tensor("Gemm_classifier.weight", (10, cin), cb, initializer=True))
    tensors.append(_tensor("Gemm_classifier.bias", (10,), acc_of(cb), initializer=True))
    logits = fresh("logits")
    tensors.append(_tensor(logits, (10,), acc_of(cb)))
    nodes.append({
        "name": "Gemm_classifier", "op_type": "Gemm",
        "inputs": [flat, "Gemm_classifier.weight", "Gemm_classifier.bias"],
        "outputs": [logits], "attributes": {},
    })
    q_logits = fresh("qlogits")
    tensors.append(_tensor(q_logits, (10,), 8))
    nodes.append({
        "name": "Quant_classifier", "op_type": "Quant",
        "inputs": [logits], "outputs": [q_logits],
        "attributes": {"bits": 8, "signed": True, "channelwise": False},
    })

    return {
        "name": cfg.name,
        "graph_inputs": ["x0"],
        "graph_outputs": [q_logits],
        "tensors": tensors,
        "nodes": nodes,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=str(Path(__file__).parents[2] / "artifacts"))
    ap.add_argument("--width", type=float, default=1.0)
    args = ap.parse_args()
    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    for name, factory in model.ALL_CASES.items():
        cfg = factory(width=args.width)
        doc = export_case(cfg)
        path = out / f"{name}.qonnx.json"
        path.write_text(json.dumps(doc, indent=1))
        print(f"wrote {path} ({len(doc['nodes'])} nodes)")


if __name__ == "__main__":
    main()
