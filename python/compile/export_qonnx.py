"""Export the JAX MobileNetV1 case configurations as QONNX-dialect JSON —
the same dialect `rust/src/graph/qonnx.rs` imports. Closes the toolchain
loop: the exact network that is trained/quantized/AOT-compiled in python
can be re-analyzed by the rust pipeline from a file.

Usage: python -m compile.export_qonnx [--out-dir ../artifacts] [--width 0.25]

Synthetic-scale mode (stdlib only — no JAX required, runnable as a plain
script) generates production-size documents with deterministic initializer
payloads for the streaming-ingest benchmark, writing the payload arrays
incrementally so even a >=100 MB document never materializes in memory:

    python python/compile/export_qonnx.py --synthetic-scale resnet50 \
        --out artifacts/resnet50_synth.qonnx.json [--target-mb 8]
"""

from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

try:  # JAX-bound; absent in bench/CI environments and plain-script runs
    from . import model
except ImportError:
    model = None


def _tensor(name, dims, bits, signed=True, initializer=False):
    return {
        "name": name,
        "dims": list(dims),
        "bits": int(bits),
        "signed": signed,
        "initializer": initializer,
    }


def export_case(cfg: model.CaseConfig) -> dict:
    """Build the QONNX-dialect document for one Table-I case."""
    pilot_c, blocks = model.channel_plan(cfg.width_mult)
    tensors = [_tensor("x0", (3, 32, 32), 8)]
    nodes = []
    edge = "x0"
    h = w = 32
    counter = [0]

    def fresh(prefix):
        counter[0] += 1
        return f"{prefix}_{counter[0]}"

    def conv(name, cin, cout, k, stride, pad, groups, w_bits, acc_bits, out_bits):
        nonlocal edge, h, w
        wname, bname = f"{name}.weight", f"{name}.bias"
        tensors.append(_tensor(wname, (cout, cin // groups, k, k), w_bits, initializer=True))
        tensors.append(_tensor(bname, (cout,), acc_bits, initializer=True))
        oh = (h + 2 * pad - k) // stride + 1
        ow = (w + 2 * pad - k) // stride + 1
        acc_edge = fresh("acc")
        tensors.append(_tensor(acc_edge, (cout, oh, ow), acc_bits))
        nodes.append({
            "name": name,
            "op_type": "Conv",
            "inputs": [edge, wname, bname],
            "outputs": [acc_edge],
            "attributes": {
                "kernel_shape": [k, k], "strides": [stride, stride],
                "pads": [pad, pad], "group": groups,
            },
        })
        # relu
        r_edge = fresh("r")
        tensors.append(_tensor(r_edge, (cout, oh, ow), acc_bits))
        nodes.append({
            "name": name.replace("Conv", "Relu").replace("Gemm", "Relu"),
            "op_type": "Relu", "inputs": [acc_edge], "outputs": [r_edge],
            "attributes": {},
        })
        # quant
        q_edge = fresh("q")
        tensors.append(_tensor(q_edge, (cout, oh, ow), out_bits))
        nodes.append({
            "name": name.replace("Conv", "Quant"),
            "op_type": "Quant", "inputs": [r_edge], "outputs": [q_edge],
            "attributes": {"bits": out_bits, "signed": True, "channelwise": True},
        })
        edge, h, w = q_edge, oh, ow
        return cout

    def acc_of(bits):
        return 16 if bits < 8 else 32

    cin = conv("Conv_pilot", 3, pilot_c, 3, 1, 1, 1,
               cfg.pilot_bits, acc_of(cfg.pilot_bits), cfg.pilot_bits)
    for i, (cout, stride) in enumerate(blocks, start=1):
        bits = cfg.block_bits[i - 1]
        cin = conv(f"Conv_dw{i}", cin, cin, 3, stride, 1, cin, bits, acc_of(bits), bits)
        cin = conv(f"Conv_pw{i}", cin, cout, 1, 1, 0, 1, bits, acc_of(bits), bits)

    # global average pool + flatten + classifier
    pool_out = fresh("pool")
    tensors.append(_tensor(pool_out, (cin, 1, 1), cfg.block_bits[-1]))
    nodes.append({
        "name": "AvgPool_head", "op_type": "AveragePool",
        "inputs": [edge], "outputs": [pool_out],
        "attributes": {"kernel_shape": [h, w]},
    })
    flat = fresh("flat")
    tensors.append(_tensor(flat, (cin,), cfg.block_bits[-1]))
    nodes.append({
        "name": "Flatten_head", "op_type": "Flatten",
        "inputs": [pool_out], "outputs": [flat], "attributes": {},
    })
    cb = cfg.classifier_bits
    tensors.append(_tensor("Gemm_classifier.weight", (10, cin), cb, initializer=True))
    tensors.append(_tensor("Gemm_classifier.bias", (10,), acc_of(cb), initializer=True))
    logits = fresh("logits")
    tensors.append(_tensor(logits, (10,), acc_of(cb)))
    nodes.append({
        "name": "Gemm_classifier", "op_type": "Gemm",
        "inputs": [flat, "Gemm_classifier.weight", "Gemm_classifier.bias"],
        "outputs": [logits], "attributes": {},
    })
    q_logits = fresh("qlogits")
    tensors.append(_tensor(q_logits, (10,), 8))
    nodes.append({
        "name": "Quant_classifier", "op_type": "Quant",
        "inputs": [logits], "outputs": [q_logits],
        "attributes": {"bits": 8, "signed": True, "channelwise": False},
    })

    return {
        "name": cfg.name,
        "graph_inputs": ["x0"],
        "graph_outputs": [q_logits],
        "tensors": tensors,
        "nodes": nodes,
    }


# ---- synthetic-scale generation (stdlib only) -------------------------------


class _Synth:
    """Accumulates a valid QONNX-dialect network (conv/relu/quant chains,
    residual adds, pool/flatten/gemm head) whose initializer tensors carry
    a `_data_len` marker instead of inline data — the writer streams the
    payload values out without ever holding them in memory."""

    def __init__(self):
        self.tensors = []
        self.nodes = []
        self.shapes = {}
        self.counter = 0
        self.payload_values = 0

    def _fresh(self, prefix):
        self.counter += 1
        return f"{prefix}_{self.counter}"

    def tensor(self, name, dims, bits, signed=True, initializer=False, data_len=None):
        t = _tensor(name, dims, bits, signed, initializer)
        if data_len is not None:
            t["_data_len"] = data_len
            self.payload_values += data_len
        self.tensors.append(t)
        return name

    def input(self, chw, bits=8):
        name = self.tensor("x0", chw, bits)
        self.shapes[name] = tuple(chw)
        return name

    def conv(self, name, x, cout, k, stride, pad, groups=1, out_bits=8):
        """Conv -> Relu -> Quant, the dialect's canonical layer triple."""
        c, h, w = self.shapes[x]
        wname = self.tensor(
            f"{name}.weight", (cout, c // groups, k, k), 8, initializer=True,
            data_len=cout * (c // groups) * k * k,
        )
        bname = self.tensor(f"{name}.bias", (cout,), 32, initializer=True, data_len=cout)
        oh = (h + 2 * pad - k) // stride + 1
        ow = (w + 2 * pad - k) // stride + 1
        acc = self.tensor(self._fresh("acc"), (cout, oh, ow), 32)
        self.nodes.append({
            "name": name, "op_type": "Conv",
            "inputs": [x, wname, bname], "outputs": [acc],
            "attributes": {
                "kernel_shape": [k, k], "strides": [stride, stride],
                "pads": [pad, pad], "group": groups,
            },
        })
        r = self.tensor(self._fresh("r"), (cout, oh, ow), 32)
        self.nodes.append({
            "name": f"{name}.relu", "op_type": "Relu",
            "inputs": [acc], "outputs": [r], "attributes": {},
        })
        q = self.tensor(self._fresh("q"), (cout, oh, ow), out_bits)
        self.nodes.append({
            "name": f"{name}.quant", "op_type": "Quant",
            "inputs": [r], "outputs": [q],
            "attributes": {"bits": out_bits, "signed": True, "channelwise": True},
        })
        self.shapes[acc] = self.shapes[r] = self.shapes[q] = (cout, oh, ow)
        return q

    def add(self, name, a, b, bits=8):
        shape = self.shapes[a]
        assert self.shapes[b] == shape, f"residual shape mismatch at {name}"
        out = self.tensor(self._fresh("sum"), shape, bits)
        self.nodes.append({
            "name": name, "op_type": "Add",
            "inputs": [a, b], "outputs": [out], "attributes": {},
        })
        self.shapes[out] = shape
        return out

    def head(self, x, classes=10):
        c, h, w = self.shapes[x]
        pool = self.tensor(self._fresh("pool"), (c, 1, 1), 8)
        self.nodes.append({
            "name": "AvgPool_head", "op_type": "AveragePool",
            "inputs": [x], "outputs": [pool],
            "attributes": {"kernel_shape": [h, w]},
        })
        flat = self.tensor(self._fresh("flat"), (c,), 8)
        self.nodes.append({
            "name": "Flatten_head", "op_type": "Flatten",
            "inputs": [pool], "outputs": [flat], "attributes": {},
        })
        wname = self.tensor("Gemm_head.weight", (classes, c), 8, initializer=True,
                            data_len=classes * c)
        bname = self.tensor("Gemm_head.bias", (classes,), 32, initializer=True,
                            data_len=classes)
        logits = self.tensor(self._fresh("logits"), (classes,), 32)
        self.nodes.append({
            "name": "Gemm_head", "op_type": "Gemm",
            "inputs": [flat, wname, bname], "outputs": [logits], "attributes": {},
        })
        q = self.tensor(self._fresh("qlogits"), (classes,), 8)
        self.nodes.append({
            "name": "Quant_head", "op_type": "Quant",
            "inputs": [logits], "outputs": [q],
            "attributes": {"bits": 8, "signed": True, "channelwise": False},
        })
        return q


def _ch(c, width):
    return max(1, int(round(c * width)))


def _synth_lenet(width):
    b = _Synth()
    e = b.input((3, 32, 32))
    e = b.conv("conv1", e, _ch(16, width), 3, 1, 1)
    e = b.conv("conv2", e, _ch(32, width), 3, 2, 1)
    e = b.conv("conv3", e, _ch(64, width), 3, 2, 1)
    return b, b.head(e)


def _synth_mobilenet(width):
    b = _Synth()
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2)] + \
        [(512, 1)] * 5 + [(1024, 2), (1024, 1)]
    cin = _ch(32, width)
    e = b.conv("stem", b.input((3, 64, 64)), cin, 3, 2, 1)
    for i, (cout, stride) in enumerate(plan, start=1):
        e = b.conv(f"dw{i}", e, cin, 3, stride, 1, groups=cin)
        cin = _ch(cout, width)
        e = b.conv(f"pw{i}", e, cin, 1, 1, 0)
    return b, b.head(e)


def _synth_resnet50(width):
    b = _Synth()
    cin = _ch(64, width)
    e = b.conv("stem", b.input((3, 64, 64)), cin, 3, 1, 1)
    stages = [(3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2), (3, 512, 2048, 2)]
    idx = 0
    for blocks, mid, out, first_stride in stages:
        for bi in range(blocks):
            idx += 1
            stride = first_stride if bi == 0 else 1
            mid_c, out_c = _ch(mid, width), _ch(out, width)
            skip = e
            m = b.conv(f"res{idx}a", e, mid_c, 1, 1, 0)
            m = b.conv(f"res{idx}b", m, mid_c, 3, stride, 1)
            m = b.conv(f"res{idx}c", m, out_c, 1, 1, 0)
            if stride != 1 or cin != out_c:
                skip = b.conv(f"res{idx}p", skip, out_c, 1, stride, 0)
            e = b.add(f"res{idx}add", m, skip)
            cin = out_c
    return b, b.head(e)


_SYNTH_ARCHS = {
    "lenet": _synth_lenet,
    "mobilenet": _synth_mobilenet,
    "resnet50": _synth_resnet50,
}

# deterministic payload tile: one period of the value pattern
_TILE = [(j * 31 + 7) % 251 - 125 for j in range(251)]


def _write_payload(fh, offset, count):
    """Stream `count` deterministic integers as a JSON array body."""
    chunk = []
    first = True
    for j in range(offset, offset + count):
        chunk.append(str(_TILE[j % 251]))
        if len(chunk) >= 65536:
            fh.write(("" if first else ",") + ",".join(chunk))
            first = False
            chunk = []
    if chunk:
        fh.write(("" if first else ",") + ",".join(chunk))


def write_synthetic(path, name, builder, out_edge):
    """Write the document incrementally: skeleton via json.dumps, payload
    arrays streamed in chunks (constant memory at any document size)."""
    offset = 0
    with open(path, "w") as fh:
        fh.write("{\n \"name\": %s,\n" % json.dumps(name))
        fh.write(" \"graph_inputs\": [\"x0\"],\n")
        fh.write(" \"graph_outputs\": %s,\n" % json.dumps([out_edge]))
        fh.write(" \"tensors\": [\n")
        for i, t in enumerate(builder.tensors):
            data_len = t.pop("_data_len", None)
            head = json.dumps(t)
            if data_len is None:
                fh.write("  " + head)
            else:
                fh.write("  " + head[:-1] + ", \"data\": [")
                _write_payload(fh, offset, data_len)
                offset += data_len
                fh.write("]}")
            fh.write(",\n" if i + 1 < len(builder.tensors) else "\n")
        fh.write(" ],\n \"nodes\": [\n")
        for i, n in enumerate(builder.nodes):
            fh.write("  " + json.dumps(n))
            fh.write(",\n" if i + 1 < len(builder.nodes) else "\n")
        fh.write(" ]\n}\n")


def synthesize(arch, target_mb=None):
    """Build `arch` at the width that lands near `target_mb` of JSON text
    (full scale when None). Returns the builder and its output edge."""
    build = _SYNTH_ARCHS[arch]
    width = 1.0
    if target_mb is not None:
        base, _ = build(1.0)
        # payload dominates the text; ~5 bytes per serialized value
        want_values = target_mb * 1e6 / 5.0
        width = max(0.02, min(4.0, math.sqrt(want_values / max(base.payload_values, 1))))
    builder, out_edge = build(width)
    return builder, out_edge, width


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=str(Path(__file__).parents[2] / "artifacts"))
    ap.add_argument("--width", type=float, default=1.0)
    ap.add_argument("--synthetic-scale", choices=sorted(_SYNTH_ARCHS),
                    help="generate a synthetic payload-bearing model (stdlib only)")
    ap.add_argument("--target-mb", type=float, default=None,
                    help="approximate document size for --synthetic-scale")
    ap.add_argument("--out", default=None,
                    help="output path for --synthetic-scale")
    args = ap.parse_args()

    if args.synthetic_scale:
        arch = args.synthetic_scale
        builder, out_edge, width = synthesize(arch, args.target_mb)
        path = Path(args.out or Path(args.out_dir) / f"{arch}_synth.qonnx.json")
        path.parent.mkdir(parents=True, exist_ok=True)
        write_synthetic(path, f"{arch}_synth", builder, out_edge)
        size = path.stat().st_size
        print(f"wrote {path}: {size / 1e6:.1f} MB, {len(builder.nodes)} nodes, "
              f"{builder.payload_values} payload values (width {width:.3f})")
        return

    if model is None:
        raise SystemExit(
            "JAX model import failed — only --synthetic-scale works in this "
            "environment (run as `python -m compile.export_qonnx` with JAX "
            "installed for the Table-I case export)"
        )
    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    for name, factory in model.ALL_CASES.items():
        cfg = factory(width=args.width)
        doc = export_case(cfg)
        path = out / f"{name}.qonnx.json"
        path.write_text(json.dumps(doc, indent=1))
        print(f"wrote {path} ({len(doc['nodes'])} nodes)")


if __name__ == "__main__":
    main()
