"""Kernel-vs-reference correctness: the core L1 signal.

Bit-exact equality is required (integer outputs), across randomized shapes
and bit-widths via hypothesis.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lut_matmul, qmatmul, ref, requant

RNG = np.random.default_rng(42)


def rand_int(shape, bits, rng=RNG):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return jnp.asarray(rng.integers(lo, hi + 1, size=shape), dtype=jnp.int32)


# --------------------------------------------------------------------------
# qmatmul
# --------------------------------------------------------------------------


def test_qmatmul_matches_ref_basic():
    x = rand_int((200, 27), 8)
    w = rand_int((27, 16), 8)
    b = rand_int((16,), 16)
    want = ref.qmatmul_ref(x, w, b, 123_456, 20, -128, 127)
    got = qmatmul.qmatmul(x, w, b, 123_456, 20, -128, 127)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qmatmul_relu_fusion_via_lo_zero():
    x = rand_int((64, 9), 8)
    w = rand_int((9, 8), 4)
    b = jnp.zeros(8, jnp.int32)
    got = qmatmul.qmatmul(x, w, b, 1 << 20, 21, 0, 127)
    assert int(jnp.min(got)) >= 0


def test_qmatmul_m_not_multiple_of_block():
    # exercises padding/truncation around BLOCK_M
    for m in [1, 127, 128, 129, 300]:
        x = rand_int((m, 5), 8)
        w = rand_int((5, 3), 8)
        b = rand_int((3,), 8)
        want = ref.qmatmul_ref(x, w, b, 999, 10, -8, 7)
        got = qmatmul.qmatmul(x, w, b, 999, 10, -8, 7)
        assert got.shape == (m, 3)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 64),
    n=st.integers(1, 32),
    x_bits=st.sampled_from([2, 4, 8]),
    w_bits=st.sampled_from([2, 4, 8]),
    shift=st.integers(8, 30),
    seed=st.integers(0, 2**31),
)
def test_qmatmul_property(m, k, n, x_bits, w_bits, shift, seed):
    rng = np.random.default_rng(seed)
    x = rand_int((m, k), x_bits, rng)
    w = rand_int((k, n), w_bits, rng)
    b = rand_int((n,), 16, rng)
    m_mult = int(rng.integers(1, 1 << 24))
    lo, hi = -(1 << (x_bits - 1)), (1 << (x_bits - 1)) - 1
    want = ref.qmatmul_ref(x, w, b, m_mult, shift, lo, hi)
    got = qmatmul.qmatmul(x, w, b, m_mult, shift, lo, hi)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# lut_matmul
# --------------------------------------------------------------------------


def test_lut_matmul_matches_ref_and_mac():
    lut, xl, xlo, wlo = ref.build_mul_lut(4, 8)
    x = rand_int((50, 27), 8)
    w = rand_int((27, 16), 4)
    b = rand_int((16,), 16)
    args = (999_999, 19, -8, 7)
    want_ref = ref.lut_matmul_ref(x, w, lut, xl, xlo, wlo, b, *args)
    want_mac = ref.qmatmul_ref(x, w, b, *args)
    got = lut_matmul.lut_matmul(x, w, lut, xl, xlo, wlo, b, *args)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_ref))
    # the LUT encodes exact products: LUT path == MAC path (paper §II-B)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_mac))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 32),
    n=st.integers(1, 16),
    w_bits=st.sampled_from([2, 4]),
    x_bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31),
)
def test_lut_matmul_property(m, k, n, w_bits, x_bits, seed):
    rng = np.random.default_rng(seed)
    lut, xl, xlo, wlo = ref.build_mul_lut(w_bits, x_bits)
    x = rand_int((m, k), x_bits, rng)
    w = rand_int((k, n), w_bits, rng)
    b = rand_int((n,), 12, rng)
    m_mult = int(rng.integers(1, 1 << 20))
    lo, hi = -(1 << (x_bits - 1)), (1 << (x_bits - 1)) - 1
    want = ref.qmatmul_ref(x, w, b, m_mult, 16, lo, hi)
    got = lut_matmul.lut_matmul(x, w, lut, xl, xlo, wlo, b, m_mult, 16, lo, hi)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lut_table_layout():
    lut, xl, xlo, wlo = ref.build_mul_lut(2, 3)
    assert lut.shape == (4 * 8,)
    assert xl == 8 and xlo == -4 and wlo == -2
    # spot-check: lut[(w - wlo) * xl + (x - xlo)] == w * x
    for w in range(-2, 2):
        for x in range(-4, 4):
            assert int(lut[(w - wlo) * xl + (x - xlo)]) == w * x


# --------------------------------------------------------------------------
# threshold requant
# --------------------------------------------------------------------------


def test_threshold_requant_matches_ref():
    acc = rand_int((5000,), 16)
    thr = jnp.asarray(np.sort(RNG.choice(np.arange(-30000, 30000), 15, replace=False)),
                      dtype=jnp.int32)
    want = ref.threshold_requant_ref(acc, thr, -8)
    got = requant.threshold_requant(acc, thr, -8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_threshold_requant_monotone():
    acc = jnp.arange(-1000, 1000, dtype=jnp.int32)
    thr = jnp.asarray([-500, -100, 0, 100, 400, 600, 900], dtype=jnp.int32)
    out = np.asarray(requant.threshold_requant(acc, thr, -4))
    assert (np.diff(out) >= 0).all()
    assert out.min() == -4 and out.max() == 3


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 3000),
    out_bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31),
)
def test_threshold_requant_property(n, out_bits, seed):
    rng = np.random.default_rng(seed)
    acc = rand_int((n,), 16, rng)
    t = (1 << out_bits) - 1
    thr = jnp.asarray(
        np.sort(rng.choice(np.arange(-40000, 40000), t, replace=False)), dtype=jnp.int32
    )
    lo = -(1 << (out_bits - 1))
    want = ref.threshold_requant_ref(acc, thr, lo)
    got = requant.threshold_requant(acc, thr, lo)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# dyadic requant reference self-checks
# --------------------------------------------------------------------------


def test_dyadic_requant_rounds_to_nearest():
    acc = jnp.asarray([-3, -2, -1, 0, 1, 2, 3], dtype=jnp.int32)
    # m/2^n = 1/2
    out = np.asarray(ref.dyadic_requant_ref(acc, 1, 1, -128, 127))
    np.testing.assert_array_equal(out, [-1, -1, 0, 0, 1, 1, 2])


def test_dyadic_requant_approximates_float_scale():
    rng = np.random.default_rng(3)
    acc = jnp.asarray(rng.integers(-100000, 100000, size=2000), dtype=jnp.int32)
    scale = 0.00734
    m, n = 123, 14  # not the best fit; just consistent
    m = round(scale * (1 << 24)); n = 24
    out = np.asarray(ref.dyadic_requant_ref(acc, m, n, -(1 << 20), 1 << 20))
    want = np.round(np.asarray(acc) * scale)
    assert np.max(np.abs(out - want)) <= 1


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
