"""Synthetic dataset tests: determinism, shapes, separability."""

import numpy as np

from compile import data


def test_shapes_and_dtypes():
    x, y = data.make_split(32, seed=1)
    assert x.shape == (32, 32, 32, 3)
    assert x.dtype == np.float32
    assert y.shape == (32,)
    assert set(np.unique(y)).issubset(set(range(10)))


def test_deterministic():
    x1, y1 = data.make_split(16, seed=5)
    x2, y2 = data.make_split(16, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = data.make_split(16, seed=6)
    assert not np.array_equal(x1, x3)


def test_prototypes_distinct():
    protos = data.class_prototypes()
    assert protos.shape == (10, 32, 32, 3)
    # pairwise distances well away from zero
    for i in range(10):
        for j in range(i + 1, 10):
            d = np.linalg.norm(protos[i] - protos[j])
            assert d > 1.0, (i, j, d)


def test_nearest_prototype_is_informative():
    """A trivial nearest-prototype classifier must beat chance by a wide
    margin — the dataset is learnable."""
    protos = data.class_prototypes().reshape(10, -1)
    x, y = data.make_split(256, seed=11)
    flat = x.reshape(256, -1)
    d = ((flat[:, None, :] - protos[None, :, :]) ** 2).sum(-1)
    pred = d.argmin(1)
    acc = (pred == y).mean()
    assert acc > 0.5, acc


def test_train_test_disjoint_seeds():
    xtr, ytr, xte, yte = data.train_test(n_train=64, n_test=64)
    assert xtr.shape[0] == 64 and xte.shape[0] == 64
    # different seeds: first train image differs from first test image
    assert not np.allclose(xtr[0], xte[0])
