"""L2 model tests: shapes, float/integer consistency, PTQ behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model

WIDTH = 0.25


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=0, width=WIDTH)


@pytest.fixture(scope="module")
def batch():
    x, y = data.make_split(16, seed=99)
    return jnp.asarray(x), jnp.asarray(y)


def test_channel_plan_width():
    pilot, blocks = model.channel_plan(0.25)
    assert pilot == 8
    assert blocks[0] == (16, 1)
    assert blocks[-1] == (256, 1)
    pilot_full, blocks_full = model.channel_plan(1.0)
    assert pilot_full == 32
    assert blocks_full[-1] == (1024, 1)


def test_float_forward_shapes(params, batch):
    x, _ = batch
    logits = model.float_forward(params, x, width=WIDTH)
    assert logits.shape == (16, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_float_forward_collect(params, batch):
    x, _ = batch
    acts = {}
    model.float_forward(params, x, width=WIDTH, collect=acts)
    assert "pilot" in acts and "dw10" in acts and "pw10" in acts and "pool" in acts
    # stride plan: 32 -> 16 -> 8 -> 4 -> 2 spatial
    assert acts["pw10"].shape[1:3] == (2, 2)


def test_im2col_matches_lax_conv(params, batch):
    """The integer im2col + matmul path must agree with lax convolution."""
    x, _ = batch
    xi = jnp.round(x * 10).astype(jnp.int32)
    w = jnp.asarray(
        np.random.default_rng(1).integers(-8, 8, size=(3, 3, 3, 8)), dtype=jnp.int32
    )
    patches, (b, oh, ow) = model._im2col(xi, 3, 3, 1, 1)
    got = (patches @ w.reshape(-1, 8)).reshape(b, oh, ow, 8)
    want = jax.lax.conv_general_dilated(
        xi.astype(jnp.float32),
        w.astype(jnp.float32),
        (1, 1),
        [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_im2col_stride2():
    x = jnp.arange(2 * 8 * 8 * 3, dtype=jnp.int32).reshape(2, 8, 8, 3)
    patches, (b, oh, ow) = model._im2col(x, 3, 3, 2, 1)
    assert (b, oh, ow) == (2, 4, 4)
    assert patches.shape == (2 * 16, 27)


def test_dw_conv_int_matches_lax(params):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(-100, 100, size=(2, 8, 8, 4)), dtype=jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, size=(3, 3, 1, 4)), dtype=jnp.int32)
    got = model._dw_conv_int(x, w, 1)
    want = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        (1, 1),
        [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=4,
    ).astype(jnp.int32)

    # stride-2 alignment: the historic SAME-vs-symmetric-padding bug
    got2 = model._dw_conv_int(x, w, 2)
    want2 = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        (2, 2),
        [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=4,
    ).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_calibration_stats_complete(params, batch):
    x, _ = batch
    stats = model.calibrate(params, x, width=WIDTH)
    assert stats["input"] > 0
    for i in range(1, 11):
        assert stats[f"dw{i}"] >= 0
        assert stats[f"pw{i}"] >= 0


def test_dyadic_fit_accuracy():
    for scale in [1e-4, 0.017, 0.3, 1.0, 3.7]:
        m, n = model._dyadic(scale)
        approx = m / (1 << n)
        assert abs(approx - scale) / scale < 1e-5, scale


@pytest.mark.parametrize("case_name", ["case1", "case2", "case3"])
def test_quantized_forward_runs(params, batch, case_name):
    x, _ = batch
    cfg = model.ALL_CASES[case_name](width=WIDTH)
    stats = model.calibrate(params, x, width=WIDTH)
    q = model.quantize_model(params, stats, cfg)
    logits = model.quantized_forward(q, x[:4])
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_int8_quantization_close_to_float(params, batch):
    """Case-1 (int8) logits should broadly agree with float logits in
    ranking: top-1 match on most examples of an easy batch."""
    x, _ = batch
    stats = model.calibrate(params, x, width=WIDTH)
    q = model.quantize_model(params, stats, model.case1(width=WIDTH))
    ql = model.quantized_forward(q, x)
    fl = model.float_forward(params, x, width=WIDTH)
    agree = float(jnp.mean(jnp.argmax(ql, 1) == jnp.argmax(fl, 1)))
    assert agree >= 0.75, f"int8 top-1 agreement with float only {agree}"


def test_weight_quantization_ranges(params):
    for bits in (2, 4, 8):
        w_q, s = model._quantize_tensor(params["pilot/w"], bits)
        hi = (1 << (bits - 1)) - 1
        assert w_q.max() <= hi and w_q.min() >= -hi - 1
        assert s > 0
