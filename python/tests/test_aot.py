"""AOT export tests: HLO text generation for the quantized graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, model


@pytest.fixture(scope="module")
def tiny_q():
    params = model.init_params(seed=0, width=0.25)
    x, _ = data.make_split(8, seed=3)
    stats = model.calibrate(params, jnp.asarray(x), width=0.25)
    return model.quantize_model(params, stats, model.case1(width=0.25))


def test_to_hlo_text_simple():
    def fn(a, b):
        return (jnp.matmul(a, b) + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "ENTRY" in text


def test_quantized_model_lowers_to_hlo(tiny_q, tmp_path):
    entry = aot.export_case(tiny_q, batch=4, out_path=tmp_path / "m.hlo.txt")
    text = (tmp_path / "m.hlo.txt").read_text()
    assert "HloModule" in text
    assert entry["input_shape"] == [4, 32, 32, 3]
    assert entry["output_shape"] == [4, 10]
    # the quantized graph is integer-dominant: int32 tensors must appear
    assert "s32" in text


def test_export_testset_round_trip(tmp_path):
    x, y = data.make_split(8, seed=2)
    aot.export_testset(x, y, tmp_path)
    import json

    header = json.loads((tmp_path / "testset.json").read_text())
    assert header["n"] == 8
    raw = np.frombuffer((tmp_path / "testset.bin").read_bytes(), dtype="<f4")
    np.testing.assert_allclose(raw.reshape(x.shape), x, rtol=0, atol=0)
    assert header["labels"] == [int(v) for v in y]
