import jax

# int64 is used by the dyadic requantization path; enable before any trace.
jax.config.update("jax_enable_x64", True)
